"""Elastic scaling: resume training with a different worker count.

The paper's lr rule (A.3: gamma0 = 0.045*N) makes worker-count changes a
first-class event: when N changes (scale-up, or scale-down after failures
exhaust the backup pool), we restore params/opt/EMA from the checkpoint,
rebuild the aggregation strategy and schedule for the new N, and continue —
the data pipeline step counter guarantees no sample is replayed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import AggregationConfig, TrainConfig, replace


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_workers: int
    new_workers: int
    old_backups: int
    new_backups: int
    lr_scale: float


def plan_rescale(cfg: TrainConfig, new_total: int,
                 backup_fraction: Optional[float] = None) -> RescalePlan:
    """Choose (N, b) for a new machine count, preserving the paper's
    ~4% backup fraction (N=96,b=4 optimum) unless told otherwise."""
    agg = cfg.aggregation
    frac = (backup_fraction if backup_fraction is not None
            else (agg.backup_workers / max(agg.total_workers, 1)))
    new_b = max(0, round(new_total * frac)) if agg.strategy == "backup" else 0
    new_n = new_total - new_b
    lr_scale = new_n / max(agg.num_workers, 1) \
        if cfg.optimizer.scale_lr_with_workers else 1.0
    return RescalePlan(agg.num_workers, new_n, agg.backup_workers, new_b, lr_scale)


def apply_rescale(cfg: TrainConfig, plan: RescalePlan) -> TrainConfig:
    new_agg = replace(cfg.aggregation, num_workers=plan.new_workers,
                      backup_workers=plan.new_backups)
    return replace(cfg, aggregation=new_agg)
