from repro.train import checkpoint, elastic, serve_step, train_step
from repro.train.loop import Trainer, TrainResult, run_experiment
from repro.train.train_step import build_eval_step, build_train_step, input_specs
