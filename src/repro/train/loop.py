"""The Trainer: every coordination regime behind one entry point.

The strategy (built from ``cfg.aggregation`` by
``repro.core.registry.get_strategy`` — the Trainer's only construction
path) picks the execution mode:

**Mask mode** (full_sync / backup / timeout) — SPMD steps driven by the
straggler simulator. Per step:
  1. the StragglerSimulator samples worker arrival times and the strategy
     selects the mask + iteration time (simulated seconds);
  2. the data pipeline emits the global batch (worker-sharded rows);
  3. the jitted SPMD step applies the masked aggregation + optimizer + EMA;
  4. on checkpoint cadence, state is committed atomically.

With ``cfg.chunk_size > 1`` the hot loop is fused: K iterations run in a
single ``lax.scan`` dispatch (see docs/perf.md); chunk boundaries are
forced at checkpoint / kill-injection / rescale steps so resume semantics
are unchanged.

With ``cfg.execution.backend == 'spmd'`` mask strategies execute on the
SPMD engine (``repro.distributed.spmd_engine``, docs/spmd.md): the W
workers map onto a real mesh 'data' axis, per-worker gradients live on
their shard, and masked aggregation is a collective — with the same
host-planned masks, checkpoint format, and chunking rules as the
simulated backend. ``mesh_model > 1`` additionally shards params/opt
state/EMA over the mesh 'model' axis and computes each worker's
gradient tensor-parallel (``sharding.tp_plan`` decides which groups
shard; checkpoints stay interchangeable — state is gathered at save
and re-sharded on restore). Strategies without SPMD support
(``registry.supports_spmd``; TP opt-out ``spmd_tp_supported``) fall
back to 'sim' with a warning.

**Event mode** (async / softsync / staleness) — the discrete-event
parameter-server loop: the scheduler pops gradient arrivals per the
latency model, the strategy decides apply-or-buffer per arrival
(paper Alg. 1/2 semantics for async), and each applied update advances
``step``. Event regimes get checkpoint/resume (exact replay: worker
parameter copies, scheduler queue and RNG are all checkpointed), EMA,
failure injection, and the same metrics schema as mask mode.

With ``cfg.chunk_size > 1`` event mode is fused too: the host scheduler
cheaply precomputes a block of arrivals into flat arrays
(``coordination.plan_events`` — the apply/staleness verdicts of every
built-in event strategy are gradient-independent), and a single
``lax.scan`` (``build_event_chunk_step``) runs gradients, strategy
application, optimizer and EMA on device, with the per-worker read
copies held as ONE stacked ``[W, ...]`` device pytree updated by
scatter. Chunk boundaries always land on PS-update counts and are
forced at checkpoint/kill steps, so resume/failure semantics — and the
on-disk checkpoint format — are identical to the per-arrival path.

Unified per-update metrics (both modes, see docs/api.md):
    ``step, loss, sim_time, selected, staleness``
plus ``TrainResult.mean_selected`` (the *actual* mean aggregated-worker
count — for Timeout this is the realized per-step mean, not the
``effective_n()`` upper bound) and ``TrainResult.mean_staleness``.

Failure handling (mask mode): a dead worker's gradient never arrives.
While alive >= N the protocol absorbs it with zero downtime (the paper's
point); below that the Trainer executes an elastic restart from the last
checkpoint. In event mode a killed worker simply stops producing
arrivals. ``run_experiment(cfg)`` is the one-call entry point used by the
CLI, the examples, and the benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core import coordination
from repro.core import ema as ema_lib
from repro.core import faults as faults_lib
from repro.core import registry
from repro.core import straggler_jax
from repro.core.events import StragglerSimulator
from repro.core.straggler import LatencyModel, PaperCalibrated
from repro.data.synthetic_lm import (ChunkPrefetcher, PipelineState,
                                     SyntheticLMConfig, SyntheticLMPipeline,
                                     device_batch_fn, worker_batch)
from repro.distributed import spmd_engine
from repro.models import get_model
from repro.obs.trace import as_tracer
from repro.optim import make_optimizer, schedules
from repro.train import checkpoint as ckpt_lib
from repro.train import elastic
from repro.train.train_step import (build_chunk_step, build_event_chunk_step,
                                    build_train_step)


@dataclasses.dataclass
class TrainResult:
    params: Any
    ema: Any
    metrics: List[Dict]
    sim_time: float
    steps: int
    restarts: int
    # realized coordination statistics (unified across mask/event modes):
    # mean gradients aggregated per update (Timeout reports its *actual*
    # per-step mean, not the effective_n() upper bound), and the mean
    # staleness of applied gradients (0 for synchronous strategies).
    mean_selected: float = 0.0
    mean_staleness: float = 0.0
    # structured fault/recovery events (chaos engine + supervisor) — the
    # schema is docs/api.md "Recovery events"; empty without fault injection.
    # Deterministic in (fault spec, fault seed): no wall-clock fields.
    recovery_log: List[Dict] = dataclasses.field(default_factory=list)
    # host wall-clock of run() (always measured — two clock reads) and,
    # when observability is on (tracer/metrics/measured mode), the
    # fenced per-phase breakdown {dispatch_s, data_s, ckpt_s}
    wall_time_s: float = 0.0
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)


def _normalize_kills(kill_worker_at: Optional[Dict[int, Any]]
                     ) -> Dict[int, List[int]]:
    """{step: worker | [workers]} -> {step: [workers]} (back-compat: the
    original API took one worker id per step)."""
    out: Dict[int, List[int]] = {}
    for s, ws in (kill_worker_at or {}).items():
        if isinstance(ws, (list, tuple, np.ndarray)):
            out[int(s)] = [int(w) for w in ws]
        else:
            out[int(s)] = [int(ws)]
    return out


class Trainer:
    def __init__(self, cfg: TrainConfig, latency: Optional[LatencyModel] = None,
                 data_cfg: Optional[SyntheticLMConfig] = None,
                 model=None, batch_fn: Optional[Callable] = None,
                 injector: Optional[faults_lib.FaultInjector] = None,
                 tracer=None, metrics=None):
        """``model``/``batch_fn`` override the config-derived model and
        per-worker batch source (event mode only) — how non-LM rigs like
        the §2.1 MNIST staleness experiment route through run_experiment.
        batch_fn(worker, draw_index) -> batch dict.

        ``injector`` attaches a chaos-engine fault plan (repro.core.faults);
        the supervisor owns it across restarts so faults fire at most once.

        ``tracer`` (repro.obs.Tracer) records train/chunk, train/step,
        train/data_wait, train/device_wait and train/ckpt_save spans;
        ``metrics`` (repro.obs.MetricsRegistry) accumulates the train/*
        schema. Either being set — or the strategy running with
        ``latency_source='measured'`` — turns on block_until_ready
        fences at chunk edges (never inside the fused scan), so chunk
        timings are real; with both unset the loop is untouched (the
        no-op tracer path, held under 2%% overhead by tests/test_obs.py).
        """
        self.cfg = cfg
        self.latency = latency or PaperCalibrated()
        self.injector = injector
        self.restarts = 0
        self.sim_time = 0.0
        self.metrics: List[Dict] = []
        self._model_override = model
        self._batch_fn_override = batch_fn
        # realized selected/staleness accumulators behind TrainResult's
        # mean_selected / mean_staleness (persisted across checkpoints)
        self._sel_sum = 0.0
        self._sel_count = 0
        self._stal_sum = 0.0
        self._stal_count = 0
        w = cfg.aggregation.total_workers
        self.data_cfg = data_cfg or SyntheticLMConfig(
            vocab_size=cfg.model.vocab_size, seq_len=cfg.shape.seq_len,
            global_batch=cfg.shape.global_batch, num_workers=w, seed=cfg.seed)
        self.tracer = as_tracer(tracer)
        self.registry = metrics
        self._wall_s = 0.0
        self._phase = {"dispatch_s": 0.0, "data_s": 0.0, "ckpt_s": 0.0}
        self._build()
        # measured mode: feed fenced wall-clock per-worker rows into the
        # strategy's adaptation window (dynamic_backup, docs/observability)
        self._measured_feed = (
            getattr(self.strategy, "latency_source", "sim") == "measured")
        self._obs = (self.tracer.enabled or self.registry is not None
                     or self._measured_feed)

    # -- construction ---------------------------------------------------------

    def _build(self) -> None:
        # the registry is the ONLY config->strategy construction path
        self.strategy = registry.get_strategy(self.cfg.aggregation)
        backend = self.cfg.execution.backend
        if backend not in ("sim", "spmd"):
            raise ValueError(f"unknown execution backend {backend!r} "
                             f"(valid: sim, spmd)")
        # the supports_spmd gate: strategies without SPMD support (event
        # regimes, opted-out plugins — incl. TP-specific opt-outs when
        # mesh_model > 1) fall back to the simulated backend
        self._spmd = backend == "spmd"
        if self._spmd and not registry.supports_spmd(self.strategy,
                                                     self.cfg.execution):
            warnings.warn(
                f"strategy {self.cfg.aggregation.strategy!r} has no SPMD "
                "support (registry.supports_spmd); falling back to the "
                "single-device simulated backend", stacklevel=2)
            self._spmd = False
        if self.strategy.kind == "mask":
            self._build_mask()
        elif self.strategy.kind == "event":
            self._build_event()
        else:
            raise ValueError(f"strategy {self.cfg.aggregation.strategy!r} has "
                             f"unknown kind {self.strategy.kind!r}")

    def _build_mask(self) -> None:
        cfg = self.cfg
        self.model = self._model_override or get_model(cfg.model)
        if self._batch_fn_override is not None:
            raise ValueError("batch_fn overrides are only supported for "
                             "event strategies (async/softsync/staleness)")
        self.sim = StragglerSimulator(self.strategy, self.latency, cfg.seed)
        sched = schedules.from_config(cfg.optimizer, cfg.aggregation.num_workers)
        self.optimizer = make_optimizer(cfg.optimizer, sched)
        self.pipeline = SyntheticLMPipeline(
            dataclasses.replace(self.data_cfg,
                                num_workers=cfg.aggregation.total_workers))
        step_kwargs = dict(
            num_workers=cfg.aggregation.total_workers,
            n_aggregate=cfg.aggregation.num_workers,
            ema_decay=cfg.optimizer.ema_decay,
            clip_norm=cfg.optimizer.clip_global_norm)
        if cfg.straggler_backend not in ("host", "device"):
            raise ValueError(f"unknown straggler_backend "
                             f"{cfg.straggler_backend!r} (host|device)")
        if (cfg.straggler_backend == "device"
                and not getattr(self.strategy, "device_select_supported", True)):
            raise ValueError(
                f"strategy {cfg.aggregation.strategy!r} selects on the host "
                "(stateful adaptation has no traceable select_jax); use "
                "straggler_backend='host'")
        if self.injector is not None and cfg.straggler_backend == "device":
            raise ValueError(
                "fault injection composes with host-planned arrivals only: "
                "straggler_backend must be 'host' when cfg.faults is active")
        if self._spmd:
            # SPMD execution engine: workers over the mesh 'data' axis,
            # masked aggregation as a collective (docs/spmd.md). Masks
            # stay host-planned, so the straggler simulator/prefetcher
            # plumbing is shared with the simulated backend.
            if cfg.straggler_backend == "device":
                raise ValueError(
                    "straggler_backend='device' applies to the simulated "
                    "backend only: the spmd engine consumes host-planned "
                    "masks (use straggler_backend='host')")
            self.mesh = spmd_engine.build_mesh(cfg.execution)
            spmd_engine.validate_layout(cfg.aggregation.total_workers,
                                        cfg.shape.global_batch,
                                        cfg.execution.mesh_data)
            # mesh_model > 1 shards params/opt/EMA over the 'model' axis
            # (tensor parallelism inside the per-worker gradient) when the
            # model config permits — sharding.tp_plan decides; a model
            # override has no config, so the axis stays replicated there
            engine_kwargs = dict(step_kwargs,
                                 use_kernel=cfg.execution.use_kernel,
                                 interpret=cfg.execution.interpret,
                                 grad_batch=cfg.execution.grad_batch,
                                 bucket_size=cfg.execution.bucket_size,
                                 model_cfg=(None if self._model_override
                                            else cfg.model))
            engine_tracer = (self.tracer
                             if getattr(self, "tracer", None) is not None
                             and self.tracer.enabled else None)
            self.train_step = spmd_engine.make_train_step(
                self.model, self.optimizer, self.mesh,
                tracer=engine_tracer, **engine_kwargs)
            if cfg.chunk_size > 1:
                self.chunk_step = spmd_engine.make_chunk_step(
                    self.model, self.optimizer, self.mesh,
                    tracer=engine_tracer, **engine_kwargs)
                self.prefetcher = ChunkPrefetcher(
                    self.pipeline.cfg, depth=cfg.prefetch_depth)
            self.step = 0
            return
        step_fn = build_train_step(self.model, self.optimizer, **step_kwargs)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        # fused chunked path: K steps per dispatch via lax.scan (see
        # docs/perf.md). 'host' backend replays the numpy straggler streams
        # bit-exactly; 'device' samples arrivals inside the scan body.
        if cfg.chunk_size > 1:
            self.chunk_step = jax.jit(
                build_chunk_step(self.model, self.optimizer, **step_kwargs),
                donate_argnums=(0, 1, 2))
            if cfg.straggler_backend == "device":
                self.chunk_step_device = jax.jit(
                    build_chunk_step(
                        self.model, self.optimizer, **step_kwargs,
                        sample_fn=straggler_jax.sampler_for(self.latency),
                        select_fn=self.strategy.select_jax,
                        data_fn=device_batch_fn(self.pipeline.cfg)),
                    static_argnums=(4,), donate_argnums=(0, 1, 2))
            self.prefetcher = ChunkPrefetcher(self.pipeline.cfg,
                                              depth=cfg.prefetch_depth)
            # domain-separated from device_batch_fn's data key stream
            self._chunk_key = jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed), 0x57A6)
        elif cfg.straggler_backend == "device":
            raise ValueError(
                "straggler_backend='device' requires chunk_size > 1 — the "
                "device backend lives inside the fused chunk dispatch")
        self.step = 0

    def _build_event(self) -> None:
        cfg = self.cfg
        if cfg.straggler_backend != "host":
            raise ValueError(
                "event strategies (async/softsync/staleness) schedule "
                "arrivals on the host: straggler_backend must be 'host'")
        self._event_fused = cfg.chunk_size > 1
        if self._event_fused and not registry.supports_event_scan(self.strategy):
            # plugins that only implement on_arrival still run — on the
            # legacy per-arrival path, with a warning instead of an error
            warnings.warn(
                f"strategy {cfg.aggregation.strategy!r} does not implement "
                "the chunked plan/scan protocol (plan_arrival + "
                "on_arrival_scan); falling back to the legacy per-arrival "
                "path (chunk_size=1 semantics)", stacklevel=2)
            self._event_fused = False
        self.model = self._model_override or get_model(cfg.model)
        sched = schedules.from_config(cfg.optimizer, cfg.aggregation.num_workers)
        self.optimizer = make_optimizer(cfg.optimizer, sched)
        self._grad_fn = coordination.make_grad_fn(self.model)
        self._update_fn = coordination.make_update_fn(
            self.optimizer, cfg.optimizer.clip_global_norm)
        if self._event_fused:
            # fused event engine: K arrivals per lax.scan dispatch; the
            # carry (params/opt/ema/stacked workers/strategy aux) stays
            # device-resident between chunks, so donate all of it
            self._event_chunk = jax.jit(
                build_event_chunk_step(self._grad_fn, self._update_fn,
                                       self.strategy,
                                       ema_decay=cfg.optimizer.ema_decay),
                donate_argnums=(0, 1, 2, 3, 4))
        if self._batch_fn_override is not None:
            self._event_batch = self._batch_fn_override
            # fused stacking has to pull override batches back to host
            self._event_batch_host = lambda w, d: {
                k: np.asarray(v)
                for k, v in self._batch_fn_override(w, d).items()}
        else:
            data_cfg = dataclasses.replace(
                self.data_cfg, num_workers=self.strategy.total_workers)

            def _batch(worker: int, draw: int) -> Dict:
                b = worker_batch(data_cfg, worker, draw)
                return {k: jnp.asarray(v) for k, v in b.items()}

            self._event_batch = _batch
            # numpy twin for the fused path: the chunk is stacked on host
            # and uploaded ONCE, instead of K per-arrival device uploads
            # immediately pulled back for stacking
            self._event_batch_host = (
                lambda w, d: worker_batch(data_cfg, w, d))
        self.step = 0

    def init_state(self, seed: Optional[int] = None) -> None:
        key = jax.random.PRNGKey(self.cfg.seed if seed is None else seed)
        self.params = self.model.init(key)
        self.opt_state = self.optimizer.init(self.params)
        self.ema = (ema_lib.init(self.params)
                    if self.cfg.optimizer.ema_decay > 0 else None)
        if self.strategy.kind == "event":
            self._init_event_state()

    def _init_event_state(self) -> None:
        w = self.strategy.total_workers
        self._read_version = np.zeros(w, dtype=np.int64)
        self._draws = np.zeros(w, dtype=np.int64)
        self._arrival_count = 0
        self._event_dead: set = set()
        if self.strategy.uses_clock:
            self._sched = coordination.EventScheduler(
                w, self.latency, self.cfg.seed)
        else:
            self._sched = coordination.SerialScheduler()
        if self._event_fused:
            # device form: one stacked [W, ...] tree of worker read
            # copies + the strategy's scan carry; host form: plan state
            # (counters, staleness tags/rng) only — no gradient trees
            self._ev_state = None
            self._plan_state = self.strategy.init_plan_state(self.cfg.seed)
            self._workers_stacked = jax.tree_util.tree_map(
                lambda p: jnp.stack([p] * w), self.params)
            self._scan_aux = self.strategy.init_scan_state(self.params)
        else:
            self._read_params = [self.params for _ in range(w)]
            self._ev_state = self.strategy.init_state(self.cfg.seed)

    # -- checkpointing --------------------------------------------------------

    def _state_tree(self):
        tree = {"params": self.params, "opt": self.opt_state}
        if self.ema is not None:
            tree["ema"] = self.ema
        if self.strategy.kind == "event":
            if self._event_fused:
                if self.strategy.uses_clock:
                    tree["workers"] = self._workers_stacked
                slots = [s for _, s in getattr(self._plan_state, "fifo", [])]
                if slots:
                    # gather the ring in FIFO order -> same on-disk layout
                    # as the legacy stacked old-gradient buffer
                    idx = jnp.asarray(slots, jnp.int32)
                    tree["stale_buffer"] = jax.tree_util.tree_map(
                        lambda r: r[idx], self._scan_aux)
            else:
                if self.strategy.uses_clock:
                    tree["workers"] = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs), *self._read_params)
                buf = getattr(self._ev_state, "buffer", None)
                if buf:
                    tree["stale_buffer"] = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs), *[g for _, g in buf])
        return tree

    def _mean_meta(self) -> Dict:
        return {"sel_sum": self._sel_sum, "sel_count": self._sel_count,
                "stal_sum": self._stal_sum, "stal_count": self._stal_count}

    def save_checkpoint(self) -> str:
        meta = {
            "num_workers": self.cfg.aggregation.num_workers,
            "backup_workers": self.cfg.aggregation.backup_workers,
            "strategy": self.cfg.aggregation.strategy,
            "sim_time": self.sim_time,
            "restarts": self.restarts,
            "means": self._mean_meta(),
        }
        # adaptive strategies (dynamic_backup) persist their window/cutoff
        # so a supervisor restore resumes the adapted n, not the config's
        if hasattr(self.strategy, "state_dict"):
            meta["strategy_state"] = self.strategy.state_dict()
        if self.strategy.kind == "event":
            # the run loop checkpoints right after an applied update, where
            # the softsync window is empty by construction; a mid-window
            # snapshot would silently lose the buffered gradients on resume
            strat_state = self._plan_state if self._event_fused else self._ev_state
            if getattr(strat_state, "pending", None) or getattr(
                    strat_state, "pending_stals", None):
                raise RuntimeError(
                    "event checkpoint with a non-empty softsync window — "
                    "checkpoint only lands right after an applied update")
            if self._event_fused:
                tags = [int(tag) for tag, _ in
                        getattr(strat_state, "fifo", [])]
            else:
                tags = [int(tag) for tag, _ in
                        getattr(strat_state, "buffer", [])]
            meta["event"] = {
                "sched": self._sched.state_dict(),
                "read_version": [int(v) for v in self._read_version],
                "draws": [int(d) for d in self._draws],
                "arrival_count": int(self._arrival_count),
                "dead": sorted(int(w) for w in self._event_dead),
                "buffer_tags": tags,
                "strategy_rng": coordination.encode_rng(
                    getattr(strat_state, "rng", None)),
            }
        else:
            meta["data_state"] = self.pipeline.state.save()
            meta["dead_workers"] = [int(w) for w in
                                    np.nonzero(self.sim.dead)[0]]
        inj = self.injector
        t0 = self._now()
        with self.tracer.span("train/ckpt_save", step=int(self.step)):
            path = ckpt_lib.save(
                self.cfg.checkpoint.directory, self.step, self._state_tree(),
                meta, self.cfg.checkpoint.keep,
                retries=getattr(self.cfg.checkpoint, "write_retries", 3),
                backoff_s=getattr(self.cfg.checkpoint,
                                  "retry_backoff_s", 0.01),
                max_backoff_s=getattr(self.cfg.checkpoint,
                                      "retry_max_backoff_s", 0.25),
                jitter=getattr(self.cfg.checkpoint, "retry_jitter", 0.5),
                backoff_seed=self.cfg.seed,
                io_check=inj.ckpt_io_check if inj is not None else None,
                on_retry=(inj.on_ckpt_retry(self.step)
                          if inj is not None else None))
        if t0 is not None:
            self._phase["ckpt_s"] += time.perf_counter() - t0
        return path

    def restore_checkpoint(self, step: Optional[int] = None) -> None:
        # manifest first: the event-mode template depends on saved metadata
        # (stale-buffer length); pin the resolved step so a concurrent save
        # cannot shift "latest" between the two reads
        manifest = ckpt_lib.read_manifest(self.cfg.checkpoint.directory, step)
        tree, manifest = ckpt_lib.restore(
            self.cfg.checkpoint.directory,
            self._template(len(manifest.get("event", {}).get("buffer_tags",
                                                             []))),
            int(manifest["step"]))
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.ema = tree.get("ema")
        self.step = int(manifest["step"])
        self.sim_time = float(manifest.get("sim_time", 0.0))
        self.restarts = int(manifest.get("restarts", 0))
        means = manifest.get("means", {})
        self._sel_sum = float(means.get("sel_sum", 0.0))
        self._sel_count = int(means.get("sel_count", 0))
        self._stal_sum = float(means.get("stal_sum", 0.0))
        self._stal_count = int(means.get("stal_count", 0))
        if (hasattr(self.strategy, "load_state_dict")
                and manifest.get("strategy_state")):
            self.strategy.load_state_dict(manifest["strategy_state"])
        if self.strategy.kind == "event":
            self._restore_event_state(tree, manifest["event"])
        else:
            self.pipeline.state = PipelineState.restore(manifest["data_state"])
            # replay-exact resume: the straggler simulator is deterministic
            # in (seed, step), so aligning its step restores the arrivals
            self.sim.reset_to_step(self.step)
            # re-apply recorded deaths — but only while the cluster shape
            # is unchanged: a rescale renumbers workers, and its rebuild
            # intentionally restarts with everyone alive
            if (manifest.get("num_workers") == self.cfg.aggregation.num_workers
                    and manifest.get("backup_workers")
                    == self.cfg.aggregation.backup_workers):
                for w in manifest.get("dead_workers", []):
                    if 0 <= int(w) < self.strategy.total_workers:
                        self.sim.kill_worker(int(w))

    def _restore_event_state(self, tree, ev_meta: Dict) -> None:
        self._init_event_state()
        w = self.strategy.total_workers
        self._read_version = np.array(ev_meta["read_version"], np.int64)
        self._draws = np.array(ev_meta["draws"], np.int64)
        self._arrival_count = int(ev_meta["arrival_count"])
        self._event_dead = set(ev_meta.get("dead", []))
        self._sched.load_state_dict(ev_meta["sched"])
        tags = ev_meta.get("buffer_tags", [])
        if self._event_fused:
            if self.strategy.uses_clock:
                self._workers_stacked = tree["workers"]
            if tags:
                # scatter the FIFO-ordered buffer into ring slots 0..n-1
                # and rebase the round-robin write pointer after them
                self._scan_aux = jax.tree_util.tree_map(
                    lambda r, b: r.at[:len(tags)].set(b),
                    self._scan_aux, tree["stale_buffer"])
                self._plan_state.fifo = [(int(tag), i)
                                         for i, tag in enumerate(tags)]
                self._plan_state.writes = len(tags)
            strat_state = self._plan_state
        else:
            if self.strategy.uses_clock:
                # share one reference per distinct read version: workers
                # at the current version get the live params; a copy is
                # gathered only per divergent version (memory fix for
                # large-W async runs)
                by_version: Dict[int, Any] = {}
                self._read_params = []
                for i in range(w):
                    v = int(self._read_version[i])
                    if v not in by_version:
                        by_version[v] = (
                            self.params if v == self.step else
                            jax.tree_util.tree_map(lambda x, i=i: x[i],
                                                   tree["workers"]))
                    self._read_params.append(by_version[v])
            else:
                self._read_params = [self.params]
            if tags:
                self._ev_state.buffer = [
                    (int(tag),
                     jax.tree_util.tree_map(lambda x, i=i: x[i],
                                            tree["stale_buffer"]))
                    for i, tag in enumerate(tags)]
            strat_state = self._ev_state
        rng = getattr(strat_state, "rng", None)
        if rng is not None and ev_meta.get("strategy_rng"):
            coordination.decode_rng(rng, ev_meta["strategy_rng"])

    def _template(self, buffer_len: int = 0):
        key = jax.random.PRNGKey(0)
        params_t = jax.eval_shape(self.model.init, key)
        opt_t = jax.eval_shape(self.optimizer.init, params_t)
        tree = {"params": params_t, "opt": opt_t}
        if self.cfg.optimizer.ema_decay > 0:
            tree["ema"] = jax.eval_shape(ema_lib.init, params_t)

        def stack_t(n):
            return jax.tree_util.tree_map(
                lambda t: jax.ShapeDtypeStruct((n,) + tuple(t.shape), t.dtype),
                params_t)

        if self.strategy.kind == "event":
            if self.strategy.uses_clock:
                tree["workers"] = stack_t(self.strategy.total_workers)
            if buffer_len:
                tree["stale_buffer"] = stack_t(buffer_len)
        return tree

    # -- elastic rescale ------------------------------------------------------

    def rescale(self, new_total: int) -> None:
        """Checkpoint, rebuild for `new_total` workers, restore, continue.

        new_total is rounded down to a divisor of the global batch so the
        per-worker shard stays integral. Mask strategies only — event
        regimes absorb worker loss natively (fewer arrival sources).
        """
        if self.strategy.kind != "mask":
            raise NotImplementedError("elastic rescale applies to mask "
                                      "strategies only")
        w = max(1, new_total)
        while self.cfg.shape.global_batch % w:
            w -= 1
        self.save_checkpoint()
        prev_restarts = self.restarts
        prev_total = self.cfg.aggregation.total_workers
        plan = elastic.plan_rescale(self.cfg, w)
        self.cfg = elastic.apply_rescale(self.cfg, plan)
        if self._spmd:
            # shrink the worker axis to the largest size the new worker
            # count still divides over — the freed devices idle rather
            # than crash the run (they rejoin on the next scale-up)
            md = self.cfg.execution.mesh_data
            while w % md:
                md -= 1
            if md != self.cfg.execution.mesh_data:
                self.cfg = dataclasses.replace(
                    self.cfg, execution=dataclasses.replace(
                        self.cfg.execution, mesh_data=md))
        self._build()
        self.restore_checkpoint()
        self.restarts = prev_restarts + 1
        if self.injector is not None:
            self.injector.record("rescale", step=self.step,
                                 from_workers=prev_total,
                                 to_workers=self.cfg.aggregation.total_workers)
            # the rescaled cluster is renumbered and starts healthy: the
            # injector's per-worker effects refer to ids that no longer exist
            self.injector.dead.clear()
            self.injector.slow_active.clear()

    # -- fault injection (the chaos engine's Trainer-side primitives) ---------

    def fault_kill(self, worker: int) -> None:
        """Permanent worker crash, in whichever mode is running."""
        if self.strategy.kind == "mask":
            self.sim.kill_worker(worker)
        else:
            self._kill_event_worker(worker)

    def fault_slowdown(self, worker: int, factor: float) -> None:
        """Latency spike on one worker (factor=1.0 restores health)."""
        if self.strategy.kind == "mask":
            self.sim.set_slowdown(worker, factor)
        else:
            self._sched.set_slowdown(worker, factor)

    def fault_revive(self, worker: int) -> None:
        """A crashed worker rejoins with the *current* params."""
        if self.strategy.kind == "mask":
            self.sim.revive_worker(worker)
            return
        self._event_dead.discard(worker)
        # fresh read copy at the live version; next arrival from now
        if self._event_fused:
            self._workers_stacked = jax.tree_util.tree_map(
                lambda ws, p: ws.at[worker].set(p),
                self._workers_stacked, self.params)
        else:
            self._read_params[worker] = self.params
        self._read_version[worker] = self.step
        self._sched.revive_worker(worker, self.sim_time)

    def _event_window_empty(self) -> bool:
        """True when no softsync-style window is buffering gradients — the
        precondition for an event-mode checkpoint (see save_checkpoint)."""
        if self.strategy.kind != "event":
            return True
        state = self._plan_state if self._event_fused else self._ev_state
        return not (getattr(state, "pending", None)
                    or getattr(state, "pending_stals", None))

    def _apply_faults(self, step: int) -> None:
        """Fire every due fault from the chaos plan (repro.core.faults).

        Called at chunk boundaries in every run loop; ``_chunk_len_at``
        forces a boundary at each pending fault step, so faults land on
        the same step in the per-step, fused, and SPMD backends."""
        if self.injector is None:
            return
        inj = self.injector
        w_total = self.strategy.total_workers
        for ev in inj.take_due(step):
            w = ev.worker % w_total if ev.worker >= 0 else ev.worker
            if (ev.kind in ("crash", "slowdown", "restart")
                    and self.strategy.kind == "event"
                    and not self.strategy.uses_clock):
                raise ValueError("failure injection does not apply to serial "
                                 "rigs (the staleness strategy has a single "
                                 "logical worker)")
            if ev.kind == "crash":
                if w not in inj.dead:
                    self.fault_kill(w)
                    inj.note_crash(step, w)
            elif ev.kind == "slowdown":
                self.fault_slowdown(w, ev.factor)
                inj.note_slowdown(step, w, ev.factor, ev.duration)
            elif ev.kind == "slow_end":
                inj.note_slow_end(w)
                self.fault_slowdown(w, 1.0)
            elif ev.kind == "restart":
                if w in inj.dead:
                    self.fault_revive(w)
                    inj.note_restart(step, w)
            elif ev.kind == "ckpt_io":
                inj.arm_ckpt_failures(step, ev.fails)
            elif ev.kind == "preempt":
                if not self._event_window_empty():
                    # an event checkpoint is only legal right after an
                    # applied update; push the notice to the next one
                    inj.defer(ev, step + 1)
                    continue
                ckpted = False
                if ev.grace:
                    self.save_checkpoint()
                    ckpted = True
                inj.record("preempt", step=step, grace=ckpted)
                raise faults_lib.Preemption(step, ckpted)

    # -- the loop -------------------------------------------------------------

    def run(self, num_steps: int, kill_worker_at: Optional[Dict[int, Any]] = None,
            min_alive_behavior: str = "rescale") -> TrainResult:
        """kill_worker_at: {step: worker_id | [worker_ids]} failure
        injections (a correlated outage kills several workers at once)."""
        t0 = time.perf_counter()
        step0 = self.step
        try:
            res = self._run(num_steps, kill_worker_at, min_alive_behavior)
        finally:
            self._wall_s += time.perf_counter() - t0
            if self.registry is not None:
                self.registry.counter("train/steps").inc(self.step - step0)
                self.registry.gauge("train/wall_time_s").set(self._wall_s)
                for key, v in self._phase.items():
                    self.registry.gauge(f"train/{key}").set(v)
        # _result() ran before the finally accumulated this run's wall
        # time: restamp so the returned report carries the final figure
        return dataclasses.replace(
            res, wall_time_s=self._wall_s,
            phase_times=dict(self._phase) if self._obs else {})

    def _run(self, num_steps: int, kill_worker_at, min_alive_behavior
             ) -> TrainResult:
        kill_worker_at = _normalize_kills(kill_worker_at)
        target = self.step + num_steps
        if self.strategy.kind == "event":
            if self._event_fused:
                self._run_event_chunked(target, kill_worker_at)
            else:
                self._run_event(target, kill_worker_at)
            return self._result()
        while self.step < target:
            self._apply_faults(self.step)
            if self.step in kill_worker_at:
                # pop on application (as the event loop does): a rescale
                # renumbers the workers, so the entry must not re-apply
                # to the rebuilt, smaller simulator on the next pass
                for w in kill_worker_at.pop(self.step):
                    self.sim.kill_worker(w)
            # adaptive strategies (dynamic_backup) expose a lower liveness
            # floor than N — the protocol itself degrades gracefully
            min_alive = getattr(self.strategy, "min_alive",
                                self.cfg.aggregation.num_workers)
            if self.sim.alive < min_alive:
                if min_alive_behavior == "rescale":
                    self.rescale(self.sim.alive)
                    continue
                raise RuntimeError("insufficient live workers")
            k = self._chunk_len_at(self.step, target, kill_worker_at)
            if self.cfg.chunk_size > 1:
                # k == 1 still goes through the chunk path so the device
                # backend's streams stay invariant to chunk partitioning
                self._run_chunk(k, target, kill_worker_at)
            else:
                self._run_one_step(target)
            if (self.cfg.checkpoint.every_steps > 0
                    and self.step % self.cfg.checkpoint.every_steps == 0):
                self.save_checkpoint()
        return self._result()

    def _result(self) -> TrainResult:
        return TrainResult(
            self.params, self.ema, self.metrics, self.sim_time, self.step,
            self.restarts,
            mean_selected=self._sel_sum / max(self._sel_count, 1),
            mean_staleness=self._stal_sum / max(self._stal_count, 1),
            recovery_log=(list(self.injector.log)
                          if self.injector is not None else []),
            wall_time_s=self._wall_s,
            phase_times=dict(self._phase) if self._obs else {})

    def _chunk_len_at(self, step: int, target: int,
                      kill_worker_at: Dict[int, int]) -> int:
        """Steps from ``step`` until the next forced boundary: run target,
        checkpoint cadence, kill injection, or a pending chaos-plan fault
        — so failure handling and replay-exact resume semantics are
        untouched by chunking. Also used to predict the NEXT chunk's
        length for the prefetcher."""
        k = min(self.cfg.chunk_size, target - step)
        every = self.cfg.checkpoint.every_steps
        if every > 0:
            k = min(k, every - step % every)
        for s in kill_worker_at:
            if step < s < step + k:
                k = s - step
        if self.injector is not None:
            for s in self.injector.upcoming_steps():
                if step < s < step + k:
                    k = s - step
        return max(k, 1)

    def _next_chunk_specs(self, k: int, target: int,
                          kill_worker_at: Dict[int, int]) -> List:
        """Predicted (data_step, length) of the next ``prefetch_depth``
        chunks after the current one — what the prefetcher speculates on
        while the device runs this dispatch. Positions are data-pipeline
        steps; lengths follow the same boundary rules as the dispatch
        itself (``_chunk_len_at``), so speculation normally hits even at
        ragged checkpoint/kill boundaries — and a miss only costs the
        speculated work (generation is pure in (cfg, step))."""
        specs = []
        s = self.step + k
        d = self.pipeline.state.step + k
        for _ in range(max(self.cfg.prefetch_depth, 0)):
            if s >= target:
                break
            kk = self._chunk_len_at(s, target, kill_worker_at)
            specs.append((d, kk))
            s += kk
            d += kk
        return specs

    # -- observability hooks (no-ops unless tracer/metrics/measured) --------

    def _now(self) -> Optional[float]:
        return time.perf_counter() if self._obs else None

    def _fence(self) -> None:
        """block_until_ready at the chunk edge — the only place device
        work is ever awaited for observability, so the fused scan stays
        one dispatch and async dispatch is untouched when off."""
        with self.tracer.span("train/device_wait"):
            jax.block_until_ready(self.params)

    def _observe_chunk(self, k: int, t0: Optional[float],
                       data_s: float) -> None:
        if t0 is None:
            return
        dt = time.perf_counter() - t0
        self._phase["dispatch_s"] += dt - data_s
        self._phase["data_s"] += data_s
        if self.registry is not None:
            self.registry.histogram("train/chunk_time_s").observe(dt)
            self.registry.histogram("train/step_time_s").observe(dt / k)
        if self._measured_feed:
            # one measured per-worker row per dispatch: on a lockstep
            # mesh every live worker spends the fenced per-step wall
            # time; dead workers arrive at +inf (the estimator's
            # routing-around-crashes convention)
            per_step = (dt - data_s) / k
            row = np.where(self.sim.dead, np.inf, per_step)
            self.strategy.observe_measured(row)
            if self.registry is not None:
                h = self.registry.histogram("spmd/worker_step_s")
                for v in row[np.isfinite(row)]:
                    h.observe(float(v))

    def _run_one_step(self, target: int) -> None:
        """Legacy per-step path: one dispatch + one metrics sync per step."""
        t0 = self._now()
        with self.tracer.span("train/step", step=int(self.step)):
            td0 = self._now()
            with self.tracer.span("train/data_wait"):
                ev = self.sim.next_event()
                batch_np = self.pipeline.next()
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            data_s = time.perf_counter() - td0 if td0 is not None else 0.0
            mask = jnp.asarray(ev.mask)
            self.params, self.opt_state, self.ema, m = self.train_step(
                self.params, self.opt_state, self.ema,
                jnp.asarray(self.step, jnp.int32), batch, mask)
            if self._obs:
                self._fence()
        self._observe_chunk(1, t0, data_s)
        self.sim_time += ev.iteration_time
        self.step += 1
        selected = int(ev.mask.sum())
        self._sel_sum += selected
        self._sel_count += 1
        if self.step % self.cfg.log_every == 0 or self.step == target:
            rec = {"step": self.step, "sim_time": self.sim_time,
                   "selected": selected, "staleness": 0.0,
                   **{k: float(v) for k, v in m.items()}}
            self.metrics.append(rec)

    def _run_chunk(self, k: int, target: int,
                   kill_worker_at: Dict[int, int]) -> None:
        """Fused path: K steps in one lax.scan dispatch, one host sync."""
        step0 = jnp.asarray(self.step, jnp.int32)
        t0 = self._now()
        data_s = 0.0
        if self.cfg.straggler_backend == "device":
            # fully device-resident: batches, arrivals and masks are all
            # produced inside the scan body — no per-chunk host transfer
            with self.tracer.span("train/chunk", k=k, step=int(self.step)):
                self.pipeline.state.step += k
                dead = jnp.asarray(self.sim.dead)
                (self.params, self.opt_state, self.ema, ms, masks_dev,
                 times_dev) = self.chunk_step_device(
                    self.params, self.opt_state, self.ema, step0, k,
                    dead, self._chunk_key)
                if self._obs:
                    self._fence()
            masks = masks_dev                 # converted lazily iff logging
            times = np.asarray(times_dev, np.float64)
            self._sel_sum += float(jnp.sum(masks_dev))
            self.sim.reset_to_step(self.sim.step + k)
        else:
            with self.tracer.span("train/chunk", k=k, step=int(self.step)):
                td0 = self._now()
                with self.tracer.span("train/data_wait"):
                    chunk_np = self.prefetcher.get(
                        self.pipeline.state.step, k,
                        next_specs=self._next_chunk_specs(k, target,
                                                          kill_worker_at))
                    self.pipeline.state.step += k
                    batches = {key: jnp.asarray(v)
                               for key, v in chunk_np.items()}
                data_s = (time.perf_counter() - td0
                          if td0 is not None else 0.0)
                events = self.sim.next_events(k)
                masks = events.masks
                times = events.times
                self._sel_sum += float(masks.sum())
                self.params, self.opt_state, self.ema, ms = self.chunk_step(
                    self.params, self.opt_state, self.ema, step0, batches,
                    jnp.asarray(masks))
                if self._obs:
                    self._fence()
        self._observe_chunk(k, t0, data_s)
        self._sel_count += k
        # metrics sync only when a log record falls inside this chunk
        logged = [i for i in range(k)
                  if (self.step + i + 1) % self.cfg.log_every == 0
                  or (self.step + i + 1) == target]
        if logged:
            if not isinstance(masks, np.ndarray):
                masks = np.asarray(masks)
            ms_np = {key: np.asarray(v) for key, v in ms.items()}
        for i in range(k):
            self.sim_time += float(times[i])
            self.step += 1
            if logged and i == logged[0]:
                logged.pop(0)
                rec = {"step": self.step, "sim_time": self.sim_time,
                       "selected": int(masks[i].sum()), "staleness": 0.0,
                       **{key: float(v[i]) for key, v in ms_np.items()}}
                self.metrics.append(rec)

    # -- the event loop -------------------------------------------------------

    def _event_alive(self) -> int:
        return self.strategy.total_workers - len(self._event_dead)

    def _kill_event_worker(self, worker: int) -> None:
        if worker in self._event_dead:
            return
        self._event_dead.add(worker)
        self._sched.drop_worker(worker)
        if self._event_alive() == 0 or not self._sched.queue:
            raise RuntimeError("insufficient live workers")

    def _run_event(self, target: int,
                   kill_worker_at: Dict[int, int]) -> None:
        """Discrete-event parameter-server loop (async/softsync/staleness).

        Mirrors ``coordination.run_events`` arrival-for-arrival (the
        bit-exactness tests hold the two to the identical update and
        staleness sequence) and adds checkpoint cadence, kill injection,
        and the unified metrics records on top.
        """
        every = self.cfg.checkpoint.every_steps
        ema_decay = self.cfg.optimizer.ema_decay
        if kill_worker_at and not self.strategy.uses_clock:
            raise ValueError("failure injection does not apply to serial "
                             "rigs (the staleness strategy has a single "
                             "logical worker)")
        while self.step < target:
            self._apply_faults(self.step)
            if self.step in kill_worker_at:
                for kw in kill_worker_at.pop(self.step):
                    self._kill_event_worker(kw)
            t, w = self._sched.pop()
            batch = self._event_batch(w, int(self._draws[w]))
            self._draws[w] += 1
            loss, grads = self._grad_fn(self._read_params[w], batch)
            arrival = coordination.Arrival(
                index=self._arrival_count, worker=w, time=float(t),
                staleness=int(self.step - self._read_version[w]),
                version=self.step)
            self._arrival_count += 1
            if self.strategy.stals_per_arrival:
                self._stal_sum += arrival.staleness
                self._stal_count += 1
            ready = self.strategy.on_arrival(self._ev_state, grads, arrival)
            updated = False
            if ready is not None:
                self.params, self.opt_state, _ = self._update_fn(
                    self.params, self.opt_state, ready.grads,
                    jnp.asarray(self.step, jnp.int32))
                if ema_decay > 0:
                    self.ema = ema_lib.update(self.ema, self.params, ema_decay)
                # simulated seconds; for the serial rig the scheduler's
                # clock IS the arrival index (the legacy convention)
                self.sim_time = float(t)
                if not self.strategy.stals_per_arrival:
                    self._stal_sum += ready.staleness
                    self._stal_count += 1
                self._sel_sum += ready.selected
                self._sel_count += 1
                self.step += 1
                updated = True
                if (self.step % self.cfg.log_every == 0
                        or self.step == target):
                    self.metrics.append({
                        "step": self.step, "loss": float(loss),
                        "sim_time": self.sim_time,
                        "selected": ready.selected,
                        "staleness": float(ready.staleness)})
            # worker reads the fresh params and starts its next mini-batch
            self._read_params[w] = self.params
            self._read_version[w] = self.step
            self._sched.push(t, w)
            if updated and every > 0 and self.step % every == 0:
                self.save_checkpoint()

    def _run_event_chunked(self, target: int,
                           kill_worker_at: Dict[int, int]) -> None:
        """Fused event path: a host-planned block of arrivals per
        ``lax.scan`` dispatch (see ``coordination.plan_events`` and
        ``build_event_chunk_step``).

        Chunk lengths are counted in PS *updates* (``_chunk_len_at`` —
        the same boundary rules as mask mode), and every chunk's plan
        ends exactly on its last update, so checkpoints and kill
        injections land on identical steps, with identical state, as the
        per-arrival path.
        """
        every = self.cfg.checkpoint.every_steps
        if kill_worker_at and not self.strategy.uses_clock:
            raise ValueError("failure injection does not apply to serial "
                             "rigs (the staleness strategy has a single "
                             "logical worker)")
        while self.step < target:
            self._apply_faults(self.step)
            if self.step in kill_worker_at:
                for kw in kill_worker_at.pop(self.step):
                    self._kill_event_worker(kw)
            u = self._chunk_len_at(self.step, target, kill_worker_at)
            plan = coordination.plan_events(
                self.strategy, self._sched, self._plan_state,
                self._read_version, self._draws,
                version0=self.step, arrival0=self._arrival_count,
                num_updates=u)
            self._arrival_count += len(plan)
            batches = [self._event_batch_host(int(wk), int(d))
                       for wk, d in zip(plan.worker, plan.draw)]
            chunk_batches = {
                k: jnp.asarray(np.stack([b[k] for b in batches]))
                for k in batches[0]}
            (self.params, self.opt_state, self.ema, self._workers_stacked,
             self._scan_aux, losses) = self._event_chunk(
                self.params, self.opt_state, self.ema, self._workers_stacked,
                self._scan_aux, chunk_batches, plan.rows())
            # host bookkeeping straight off the plan — no device sync
            if self.strategy.stals_per_arrival:
                self._stal_sum += float(plan.arrival_staleness.sum())
                self._stal_count += len(plan)
            else:
                self._stal_sum += float(plan.update_staleness[plan.apply].sum())
                self._stal_count += plan.updates
            self._sel_sum += float(plan.selected[plan.apply].sum())
            self._sel_count += plan.updates
            losses_np = None          # read back only if a record logs
            for k in np.nonzero(plan.apply)[0]:
                self.step += 1
                self.sim_time = float(plan.time[k])
                if self.step % self.cfg.log_every == 0 or self.step == target:
                    if losses_np is None:
                        losses_np = np.asarray(losses)
                    self.metrics.append({
                        "step": self.step, "loss": float(losses_np[k]),
                        "sim_time": self.sim_time,
                        "selected": int(plan.selected[k]),
                        "staleness": float(plan.update_staleness[k])})
            if every > 0 and self.step % every == 0:
                self.save_checkpoint()


# ---------------------------------------------------------------------------
# The one-call entry point
# ---------------------------------------------------------------------------


def run_experiment(cfg: TrainConfig, *, latency: Optional[LatencyModel] = None,
                   data_cfg: Optional[SyntheticLMConfig] = None,
                   model=None, batch_fn: Optional[Callable] = None,
                   resume: bool = False, save_final: bool = False,
                   kill_worker_at: Optional[Dict[int, Any]] = None,
                   min_alive_behavior: str = "rescale",
                   injector: Optional[faults_lib.FaultInjector] = None,
                   tracer=None, metrics=None) -> TrainResult:
    """Run any coordination regime — full_sync, backup, timeout,
    dynamic_backup, async, softsync, staleness — from ``cfg.aggregation``
    alone.

    Builds the Trainer (strategy via the registry), initializes or resumes
    state, runs ``cfg.total_steps`` steps (PS updates in event mode), and
    returns the unified :class:`TrainResult`. ``model``/``batch_fn`` plug
    non-LM problems into event regimes (e.g. the MNIST staleness rig).

    ``cfg.faults.spec`` attaches a chaos plan (an ``injector`` argument
    overrides it — the supervisor passes its own so faults fire at most
    once across restarts). An injected ``preempt``/crash propagates out of
    this call; ``repro.train.supervisor.run_supervised`` is the entry
    point that catches it, restores, and continues.
    """
    if injector is None:
        injector = faults_lib.build_injector(
            getattr(cfg, "faults", None), num_steps=cfg.total_steps,
            num_workers=cfg.aggregation.total_workers)
    tr = Trainer(cfg, latency=latency, data_cfg=data_cfg, model=model,
                 batch_fn=batch_fn, injector=injector, tracer=tracer,
                 metrics=metrics)
    if resume and ckpt_lib.latest_step(cfg.checkpoint.directory) is not None:
        tr.restore_checkpoint()
        if injector is not None:
            injector.resync(tr)
    else:
        tr.init_state()
    res = tr.run(cfg.total_steps, kill_worker_at=kill_worker_at,
                 min_alive_behavior=min_alive_behavior)
    if save_final:
        tr.save_checkpoint()
    return res
