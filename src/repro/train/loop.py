"""The Trainer: SPMD steps driven by the straggler simulator, with
checkpoint/restart, failure injection, and elastic rescaling.

Per step:
  1. the StragglerSimulator samples worker arrival times and the strategy
     selects the mask + iteration time (simulated seconds);
  2. the data pipeline emits the global batch (worker-sharded rows);
  3. the jitted SPMD step applies the masked aggregation + optimizer + EMA;
  4. on checkpoint cadence, state is committed atomically.

Failure handling: a dead worker's gradient simply never arrives (mask
stays False). While alive >= N the protocol absorbs it with zero downtime
(the paper's point). When alive < N, the Trainer executes an elastic
restart from the last checkpoint with the reduced worker count and the
paper's lr rule re-applied.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig, replace
from repro.core import aggregation as agg_lib
from repro.core import ema as ema_lib
from repro.core.events import StragglerSimulator
from repro.core.straggler import LatencyModel, PaperCalibrated
from repro.data.synthetic_lm import SyntheticLMConfig, SyntheticLMPipeline, PipelineState
from repro.models import get_model
from repro.optim import make_optimizer, schedules
from repro.train import checkpoint as ckpt_lib
from repro.train import elastic
from repro.train.train_step import build_train_step


@dataclasses.dataclass
class TrainResult:
    params: Any
    ema: Any
    metrics: List[Dict]
    sim_time: float
    steps: int
    restarts: int


class Trainer:
    def __init__(self, cfg: TrainConfig, latency: Optional[LatencyModel] = None,
                 data_cfg: Optional[SyntheticLMConfig] = None):
        self.cfg = cfg
        self.latency = latency or PaperCalibrated()
        self.restarts = 0
        self.sim_time = 0.0
        self.metrics: List[Dict] = []
        w = cfg.aggregation.total_workers
        self.data_cfg = data_cfg or SyntheticLMConfig(
            vocab_size=cfg.model.vocab_size, seq_len=cfg.shape.seq_len,
            global_batch=cfg.shape.global_batch, num_workers=w, seed=cfg.seed)
        self._build()

    # -- construction ---------------------------------------------------------

    def _build(self) -> None:
        cfg = self.cfg
        self.model = get_model(cfg.model)
        self.strategy = agg_lib.from_config(cfg.aggregation)
        self.sim = StragglerSimulator(self.strategy, self.latency, cfg.seed)
        sched = schedules.from_config(cfg.optimizer, cfg.aggregation.num_workers)
        self.optimizer = make_optimizer(cfg.optimizer, sched)
        self.pipeline = SyntheticLMPipeline(
            dataclasses.replace(self.data_cfg,
                                num_workers=cfg.aggregation.total_workers))
        step_fn = build_train_step(
            self.model, self.optimizer,
            num_workers=cfg.aggregation.total_workers,
            n_aggregate=cfg.aggregation.num_workers,
            ema_decay=cfg.optimizer.ema_decay,
            clip_norm=cfg.optimizer.clip_global_norm)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        self.step = 0

    def init_state(self, seed: Optional[int] = None) -> None:
        key = jax.random.PRNGKey(self.cfg.seed if seed is None else seed)
        self.params = self.model.init(key)
        self.opt_state = self.optimizer.init(self.params)
        self.ema = (ema_lib.init(self.params)
                    if self.cfg.optimizer.ema_decay > 0 else None)

    # -- checkpointing --------------------------------------------------------

    def _state_tree(self):
        tree = {"params": self.params, "opt": self.opt_state}
        if self.ema is not None:
            tree["ema"] = self.ema
        return tree

    def save_checkpoint(self) -> str:
        meta = {
            "data_state": self.pipeline.state.save(),
            "num_workers": self.cfg.aggregation.num_workers,
            "backup_workers": self.cfg.aggregation.backup_workers,
            "sim_time": self.sim_time,
            "restarts": self.restarts,
        }
        return ckpt_lib.save(self.cfg.checkpoint.directory, self.step,
                             self._state_tree(), meta, self.cfg.checkpoint.keep)

    def restore_checkpoint(self, step: Optional[int] = None) -> None:
        tree, manifest = ckpt_lib.restore(self.cfg.checkpoint.directory,
                                          self._template(), step)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.ema = tree.get("ema")
        self.step = int(manifest["step"])
        self.sim_time = float(manifest.get("sim_time", 0.0))
        self.restarts = int(manifest.get("restarts", 0))
        self.pipeline.state = PipelineState.restore(manifest["data_state"])
        # replay-exact resume: the straggler simulator is deterministic in
        # (seed, step), so aligning its step restores the arrival sequence
        self.sim._step = self.step

    def _template(self):
        key = jax.random.PRNGKey(0)
        params_t = jax.eval_shape(self.model.init, key)
        opt_t = jax.eval_shape(self.optimizer.init, params_t)
        tree = {"params": params_t, "opt": opt_t}
        if self.cfg.optimizer.ema_decay > 0:
            tree["ema"] = jax.eval_shape(ema_lib.init, params_t)
        return tree

    # -- elastic rescale ------------------------------------------------------

    def rescale(self, new_total: int) -> None:
        """Checkpoint, rebuild for `new_total` workers, restore, continue.

        new_total is rounded down to a divisor of the global batch so the
        per-worker shard stays integral.
        """
        w = max(1, new_total)
        while self.cfg.shape.global_batch % w:
            w -= 1
        self.save_checkpoint()
        prev_restarts = self.restarts
        plan = elastic.plan_rescale(self.cfg, w)
        self.cfg = elastic.apply_rescale(self.cfg, plan)
        self._build()
        self.restore_checkpoint()
        self.restarts = prev_restarts + 1

    # -- the loop -------------------------------------------------------------

    def run(self, num_steps: int, kill_worker_at: Optional[Dict[int, int]] = None,
            min_alive_behavior: str = "rescale") -> TrainResult:
        """kill_worker_at: {step: worker_id} failure injections."""
        kill_worker_at = kill_worker_at or {}
        target = self.step + num_steps
        while self.step < target:
            if self.step in kill_worker_at:
                self.sim.kill_worker(kill_worker_at[self.step])
            if self.sim.alive < self.cfg.aggregation.num_workers:
                if min_alive_behavior == "rescale":
                    self.rescale(self.sim.alive)
                    continue
                raise RuntimeError("insufficient live workers")
            ev = self.sim.next_event()
            batch_np = self.pipeline.next()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            mask = jnp.asarray(ev.mask)
            self.params, self.opt_state, self.ema, m = self.train_step(
                self.params, self.opt_state, self.ema,
                jnp.asarray(self.step, jnp.int32), batch, mask)
            self.sim_time += ev.iteration_time
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == target:
                rec = {"step": self.step, "sim_time": self.sim_time,
                       "selected": int(ev.mask.sum()),
                       **{k: float(v) for k, v in m.items()}}
                self.metrics.append(rec)
            if (self.cfg.checkpoint.every_steps > 0
                    and self.step % self.cfg.checkpoint.every_steps == 0):
                self.save_checkpoint()
        return TrainResult(self.params, self.ema, self.metrics, self.sim_time,
                           self.step, self.restarts)
