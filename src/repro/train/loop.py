"""The Trainer: SPMD steps driven by the straggler simulator, with
checkpoint/restart, failure injection, and elastic rescaling.

Per step:
  1. the StragglerSimulator samples worker arrival times and the strategy
     selects the mask + iteration time (simulated seconds);
  2. the data pipeline emits the global batch (worker-sharded rows);
  3. the jitted SPMD step applies the masked aggregation + optimizer + EMA;
  4. on checkpoint cadence, state is committed atomically.

Failure handling: a dead worker's gradient simply never arrives (mask
stays False). While alive >= N the protocol absorbs it with zero downtime
(the paper's point). When alive < N, the Trainer executes an elastic
restart from the last checkpoint with the reduced worker count and the
paper's lr rule re-applied.

With ``cfg.chunk_size > 1`` the hot loop is fused: K iterations run in a
single ``lax.scan`` dispatch, the K batches (and masks) ship in one
stacked transfer, and metrics sync to host once per chunk. Chunk
boundaries are forced at checkpoint / kill-injection / rescale steps, so
failure handling and replay-exact resume are unchanged, and the default
'host' straggler backend is bit-identical to the per-step path. See
docs/perf.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig, replace
from repro.core import aggregation as agg_lib
from repro.core import ema as ema_lib
from repro.core import straggler_jax
from repro.core.events import StragglerSimulator
from repro.core.straggler import LatencyModel, PaperCalibrated
from repro.data.synthetic_lm import (ChunkPrefetcher, PipelineState,
                                     SyntheticLMConfig, SyntheticLMPipeline,
                                     device_batch_fn)
from repro.models import get_model
from repro.optim import make_optimizer, schedules
from repro.train import checkpoint as ckpt_lib
from repro.train import elastic
from repro.train.train_step import build_chunk_step, build_train_step


@dataclasses.dataclass
class TrainResult:
    params: Any
    ema: Any
    metrics: List[Dict]
    sim_time: float
    steps: int
    restarts: int


class Trainer:
    def __init__(self, cfg: TrainConfig, latency: Optional[LatencyModel] = None,
                 data_cfg: Optional[SyntheticLMConfig] = None):
        self.cfg = cfg
        self.latency = latency or PaperCalibrated()
        self.restarts = 0
        self.sim_time = 0.0
        self.metrics: List[Dict] = []
        w = cfg.aggregation.total_workers
        self.data_cfg = data_cfg or SyntheticLMConfig(
            vocab_size=cfg.model.vocab_size, seq_len=cfg.shape.seq_len,
            global_batch=cfg.shape.global_batch, num_workers=w, seed=cfg.seed)
        self._build()

    # -- construction ---------------------------------------------------------

    def _build(self) -> None:
        cfg = self.cfg
        self.model = get_model(cfg.model)
        self.strategy = agg_lib.from_config(cfg.aggregation)
        self.sim = StragglerSimulator(self.strategy, self.latency, cfg.seed)
        sched = schedules.from_config(cfg.optimizer, cfg.aggregation.num_workers)
        self.optimizer = make_optimizer(cfg.optimizer, sched)
        self.pipeline = SyntheticLMPipeline(
            dataclasses.replace(self.data_cfg,
                                num_workers=cfg.aggregation.total_workers))
        step_kwargs = dict(
            num_workers=cfg.aggregation.total_workers,
            n_aggregate=cfg.aggregation.num_workers,
            ema_decay=cfg.optimizer.ema_decay,
            clip_norm=cfg.optimizer.clip_global_norm)
        step_fn = build_train_step(self.model, self.optimizer, **step_kwargs)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        # fused chunked path: K steps per dispatch via lax.scan (see
        # docs/perf.md). 'host' backend replays the numpy straggler streams
        # bit-exactly; 'device' samples arrivals inside the scan body.
        if cfg.straggler_backend not in ("host", "device"):
            raise ValueError(f"unknown straggler_backend "
                             f"{cfg.straggler_backend!r} (host|device)")
        if cfg.chunk_size > 1:
            self.chunk_step = jax.jit(
                build_chunk_step(self.model, self.optimizer, **step_kwargs),
                donate_argnums=(0, 1, 2))
            if cfg.straggler_backend == "device":
                self.chunk_step_device = jax.jit(
                    build_chunk_step(
                        self.model, self.optimizer, **step_kwargs,
                        sample_fn=straggler_jax.sampler_for(self.latency),
                        select_fn=self.strategy.select_jax,
                        data_fn=device_batch_fn(self.pipeline.cfg)),
                    static_argnums=(4,), donate_argnums=(0, 1, 2))
            self.prefetcher = ChunkPrefetcher(self.pipeline.cfg)
            # domain-separated from device_batch_fn's data key stream
            self._chunk_key = jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed), 0x57A6)
        elif cfg.straggler_backend == "device":
            raise ValueError(
                "straggler_backend='device' requires chunk_size > 1 — the "
                "device backend lives inside the fused chunk dispatch")
        self.step = 0

    def init_state(self, seed: Optional[int] = None) -> None:
        key = jax.random.PRNGKey(self.cfg.seed if seed is None else seed)
        self.params = self.model.init(key)
        self.opt_state = self.optimizer.init(self.params)
        self.ema = (ema_lib.init(self.params)
                    if self.cfg.optimizer.ema_decay > 0 else None)

    # -- checkpointing --------------------------------------------------------

    def _state_tree(self):
        tree = {"params": self.params, "opt": self.opt_state}
        if self.ema is not None:
            tree["ema"] = self.ema
        return tree

    def save_checkpoint(self) -> str:
        meta = {
            "data_state": self.pipeline.state.save(),
            "num_workers": self.cfg.aggregation.num_workers,
            "backup_workers": self.cfg.aggregation.backup_workers,
            "sim_time": self.sim_time,
            "restarts": self.restarts,
        }
        return ckpt_lib.save(self.cfg.checkpoint.directory, self.step,
                             self._state_tree(), meta, self.cfg.checkpoint.keep)

    def restore_checkpoint(self, step: Optional[int] = None) -> None:
        tree, manifest = ckpt_lib.restore(self.cfg.checkpoint.directory,
                                          self._template(), step)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.ema = tree.get("ema")
        self.step = int(manifest["step"])
        self.sim_time = float(manifest.get("sim_time", 0.0))
        self.restarts = int(manifest.get("restarts", 0))
        self.pipeline.state = PipelineState.restore(manifest["data_state"])
        # replay-exact resume: the straggler simulator is deterministic in
        # (seed, step), so aligning its step restores the arrival sequence
        self.sim.reset_to_step(self.step)

    def _template(self):
        key = jax.random.PRNGKey(0)
        params_t = jax.eval_shape(self.model.init, key)
        opt_t = jax.eval_shape(self.optimizer.init, params_t)
        tree = {"params": params_t, "opt": opt_t}
        if self.cfg.optimizer.ema_decay > 0:
            tree["ema"] = jax.eval_shape(ema_lib.init, params_t)
        return tree

    # -- elastic rescale ------------------------------------------------------

    def rescale(self, new_total: int) -> None:
        """Checkpoint, rebuild for `new_total` workers, restore, continue.

        new_total is rounded down to a divisor of the global batch so the
        per-worker shard stays integral.
        """
        w = max(1, new_total)
        while self.cfg.shape.global_batch % w:
            w -= 1
        self.save_checkpoint()
        prev_restarts = self.restarts
        plan = elastic.plan_rescale(self.cfg, w)
        self.cfg = elastic.apply_rescale(self.cfg, plan)
        self._build()
        self.restore_checkpoint()
        self.restarts = prev_restarts + 1

    # -- the loop -------------------------------------------------------------

    def run(self, num_steps: int, kill_worker_at: Optional[Dict[int, int]] = None,
            min_alive_behavior: str = "rescale") -> TrainResult:
        """kill_worker_at: {step: worker_id} failure injections."""
        kill_worker_at = kill_worker_at or {}
        target = self.step + num_steps
        while self.step < target:
            if self.step in kill_worker_at:
                self.sim.kill_worker(kill_worker_at[self.step])
            if self.sim.alive < self.cfg.aggregation.num_workers:
                if min_alive_behavior == "rescale":
                    self.rescale(self.sim.alive)
                    continue
                raise RuntimeError("insufficient live workers")
            k = self._chunk_len_at(self.step, target, kill_worker_at)
            if self.cfg.chunk_size > 1:
                # k == 1 still goes through the chunk path so the device
                # backend's streams stay invariant to chunk partitioning
                self._run_chunk(k, target, kill_worker_at)
            else:
                self._run_one_step(target)
            if (self.cfg.checkpoint.every_steps > 0
                    and self.step % self.cfg.checkpoint.every_steps == 0):
                self.save_checkpoint()
        return TrainResult(self.params, self.ema, self.metrics, self.sim_time,
                           self.step, self.restarts)

    def _chunk_len_at(self, step: int, target: int,
                      kill_worker_at: Dict[int, int]) -> int:
        """Steps from ``step`` until the next forced boundary: run target,
        checkpoint cadence, or kill injection — so failure handling and
        replay-exact resume semantics are untouched by chunking. Also used
        to predict the NEXT chunk's length for the prefetcher."""
        k = min(self.cfg.chunk_size, target - step)
        every = self.cfg.checkpoint.every_steps
        if every > 0:
            k = min(k, every - step % every)
        for s in kill_worker_at:
            if step < s < step + k:
                k = s - step
        return max(k, 1)

    def _run_one_step(self, target: int) -> None:
        """Legacy per-step path: one dispatch + one metrics sync per step."""
        ev = self.sim.next_event()
        batch_np = self.pipeline.next()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        mask = jnp.asarray(ev.mask)
        self.params, self.opt_state, self.ema, m = self.train_step(
            self.params, self.opt_state, self.ema,
            jnp.asarray(self.step, jnp.int32), batch, mask)
        self.sim_time += ev.iteration_time
        self.step += 1
        if self.step % self.cfg.log_every == 0 or self.step == target:
            rec = {"step": self.step, "sim_time": self.sim_time,
                   "selected": int(ev.mask.sum()),
                   **{k: float(v) for k, v in m.items()}}
            self.metrics.append(rec)

    def _run_chunk(self, k: int, target: int,
                   kill_worker_at: Dict[int, int]) -> None:
        """Fused path: K steps in one lax.scan dispatch, one host sync."""
        step0 = jnp.asarray(self.step, jnp.int32)
        if self.cfg.straggler_backend == "device":
            # fully device-resident: batches, arrivals and masks are all
            # produced inside the scan body — no per-chunk host transfer
            self.pipeline.state.step += k
            dead = jnp.asarray(self.sim.dead)
            (self.params, self.opt_state, self.ema, ms, masks_dev,
             times_dev) = self.chunk_step_device(
                self.params, self.opt_state, self.ema, step0, k,
                dead, self._chunk_key)
            masks = masks_dev                 # converted lazily iff logging
            times = np.asarray(times_dev, np.float64)
            self.sim.reset_to_step(self.sim.step + k)
        else:
            next_k = (self._chunk_len_at(self.step + k, target, kill_worker_at)
                      if self.step + k < target else None)
            chunk_np = self.prefetcher.get(self.pipeline.state.step, k,
                                           next_k=next_k)
            self.pipeline.state.step += k
            batches = {key: jnp.asarray(v) for key, v in chunk_np.items()}
            events = self.sim.next_events(k)
            masks = events.masks
            times = events.times
            self.params, self.opt_state, self.ema, ms = self.chunk_step(
                self.params, self.opt_state, self.ema, step0, batches,
                jnp.asarray(masks))
        # metrics sync only when a log record falls inside this chunk
        logged = [i for i in range(k)
                  if (self.step + i + 1) % self.cfg.log_every == 0
                  or (self.step + i + 1) == target]
        if logged:
            if not isinstance(masks, np.ndarray):
                masks = np.asarray(masks)
            ms_np = {key: np.asarray(v) for key, v in ms.items()}
        for i in range(k):
            self.sim_time += float(times[i])
            self.step += 1
            if logged and i == logged[0]:
                logged.pop(0)
                rec = {"step": self.step, "sim_time": self.sim_time,
                       "selected": int(masks[i].sum()),
                       **{key: float(v[i]) for key, v in ms_np.items()}}
                self.metrics.append(rec)
