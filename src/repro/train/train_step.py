"""SPMD train-step builder: model + optimizer + the paper's aggregation.

The step signature is

    (params, opt_state, ema, step, batch, mask) ->
        (params, opt_state, ema, metrics)

where ``mask`` is the [W] backup-worker selection for THIS step (host-
computed by the StragglerSimulator; all-ones for plain Sync-Opt). The
masked aggregation is realized by weighting per-example losses (see
repro.core.sync_backup) so the normal data-parallel gradient psum performs
Alg. 4's "mean of the fastest N" exactly.

Sync-Opt needs no gradient clipping (paper §A.3) — clipping is only
applied when the config asks for it (the async simulator does).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ema as ema_lib
from repro.core import straggler_jax
from repro.core import sync_backup
from repro.optim import optimizers as opt_lib


def make_loss_fn(model, num_workers: int, n_aggregate: int) -> Callable:
    """Builds loss(params, batch, mask) -> (scalar, metrics)."""

    def loss_fn(params, batch, mask):
        per_tok, aux = model.per_token_loss(params, batch)
        labels = batch["labels"]
        if per_tok.shape[1] != labels.shape[1]:       # vlm prefix positions
            pad = per_tok.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], 1)
        valid = (labels >= 0).astype(jnp.float32)
        per_ex = (jnp.sum(per_tok * valid, axis=-1)
                  / jnp.maximum(jnp.sum(valid, axis=-1), 1.0))
        main = sync_backup.weighted_loss(per_ex, mask, n_aggregate)
        # monitoring loss: plain mean over the *selected* workers — divide
        # by the realized selection fraction so Timeout's variable counts
        # don't skew the reading
        sel = jnp.sum(per_ex * sync_backup.per_example_weights(
            mask, per_ex.shape[0], n_aggregate))
        frac = jnp.sum(mask.astype(jnp.float32)) / n_aggregate
        total = main + aux
        metrics = {"loss": sel / jnp.maximum(frac, 1e-6), "aux_loss": aux}
        return total, metrics

    return loss_fn


def _microbatch_split(batch: Dict[str, jnp.ndarray], num_workers: int,
                      num_microbatches: int) -> Dict[str, jnp.ndarray]:
    """[B, ...] -> [M, B/M, ...] such that every microbatch contains an
    equal slice of EVERY worker's shard (workers own contiguous row blocks,
    so the mask-weighted aggregation stays exact per microbatch)."""
    def split(x):
        b = x.shape[0]
        per = b // num_workers
        per_mb = per // num_microbatches
        x = x.reshape((num_workers, num_microbatches, per_mb) + x.shape[1:])
        x = jnp.swapaxes(x, 0, 1)
        return x.reshape((num_microbatches, num_workers * per_mb) + x.shape[3:])

    return jax.tree_util.tree_map(split, batch)


def build_train_step(model, optimizer: opt_lib.Optimizer, *, num_workers: int,
                     n_aggregate: int, ema_decay: float = 0.0,
                     clip_norm: float = 0.0, num_microbatches: int = 1,
                     grad_shardings: Any = None) -> Callable:
    """num_microbatches > 1 enables gradient accumulation: the batch is
    scanned in M slices and per-microbatch gradients are accumulated in an
    f32 tree. When ``grad_shardings`` is given, the accumulator is
    constrained to it (data-axes sharded => the DP all-reduce becomes a
    reduce-scatter and the accumulator stays ZeRO-2-sharded)."""
    loss_fn = make_loss_fn(model, num_workers, n_aggregate)

    def compute_grads(params, batch, mask):
        if num_microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, mask)
            if grad_shardings is not None:
                grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
            return grads, metrics

        mb = _microbatch_split(batch, num_workers, num_microbatches)

        def body(acc, mb_batch):
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb_batch, mask)
            if grad_shardings is not None:
                g = jax.lax.with_sharding_constraint(g, grad_shardings)
            acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc, g)
            return acc, metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if grad_shardings is not None:
            zeros = jax.lax.with_sharding_constraint(zeros, grad_shardings)
        acc, metrics_stack = jax.lax.scan(body, zeros, mb)
        grads = jax.tree_util.tree_map(lambda a: a / num_microbatches, acc)
        metrics = jax.tree_util.tree_map(jnp.mean, metrics_stack)
        return grads, metrics

    def train_step(params, opt_state, ema_state, step, batch, mask):
        grads, metrics = compute_grads(params, batch, mask)
        if clip_norm > 0:
            grads, gnorm = opt_lib.clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        new_params, new_opt, stats = optimizer.apply(params, grads, opt_state, step)
        metrics.update(stats)
        if ema_decay > 0:
            ema_state = ema_lib.update(ema_state, new_params, ema_decay)
        return new_params, new_opt, ema_state, metrics

    return train_step


def build_chunk_step(model, optimizer: opt_lib.Optimizer, *, num_workers: int,
                     n_aggregate: int, ema_decay: float = 0.0,
                     clip_norm: float = 0.0, num_microbatches: int = 1,
                     grad_shardings: Any = None, sample_fn: Callable = None,
                     select_fn: Callable = None,
                     data_fn: Callable = None) -> Callable:
    """Fused K-step trainer: one ``lax.scan`` dispatch per chunk.

    Host-mask mode (``sample_fn is None``) — masks precomputed by the host
    StragglerSimulator, stacked and shipped with the batch:

        chunk(params, opt, ema, step0, batches [K,B,...], masks [K,W])
            -> (params, opt, ema, metrics {k: [K]})

    Device mode (``sample_fn``/``select_fn``/``data_fn`` given) — batch
    generation, arrival sampling AND mask selection all run inside the
    scan body; sim_time accumulates in the carry and everything syncs to
    host once per chunk (``k`` is static — one compile per chunk length):

        chunk(params, opt, ema, step0, k, dead [W], key)
            -> (params, opt, ema, metrics {k: [K]}, masks [K,W], times [K])

    Both modes advance ``step`` in the carry so lr schedules see the same
    per-step values as the legacy path; the scan body is the unmodified
    ``build_train_step`` function, which XLA compiles to the same
    per-iteration arithmetic — the chunked host path is bit-identical to K
    sequential dispatches (tests/test_chunked_loop.py).
    """
    step_fn = build_train_step(
        model, optimizer, num_workers=num_workers, n_aggregate=n_aggregate,
        ema_decay=ema_decay, clip_norm=clip_norm,
        num_microbatches=num_microbatches, grad_shardings=grad_shardings)

    def scan_steps(params, opt_state, ema_state, step0, batches, masks):
        """The one scan both modes share: K steps over stacked (batch, mask)."""
        def body(carry, xs):
            p, o, e, step = carry
            batch, mask = xs
            p, o, e, m = step_fn(p, o, e, step, batch, mask)
            return (p, o, e, step + 1), m

        (p, o, e, _), ms = jax.lax.scan(
            body, (params, opt_state, ema_state, step0), (batches, masks))
        return p, o, e, ms

    if sample_fn is None:
        return scan_steps

    if select_fn is None or data_fn is None:
        raise ValueError("device mode needs sample_fn, select_fn and data_fn")

    def chunk(params, opt_state, ema_state, step0, k, dead, key):
        # All chunk randomness is generated vectorized up front
        # (straggler_jax.chunk_arrivals — per-step fold_in streams, so
        # results are invariant to chunk partitioning) instead of inside
        # the scan body: hoisting the threefry expansion keeps the scan
        # body at the bare train-step cost.
        steps = step0 + jnp.arange(k, dtype=step0.dtype)
        batches = jax.vmap(data_fn)(steps)
        arrivals = straggler_jax.chunk_arrivals(sample_fn, key, steps,
                                                dead.shape[0], dead)
        masks, times = jax.vmap(select_fn)(arrivals)
        masks = masks & ~dead[None, :]
        p, o, e, ms = scan_steps(params, opt_state, ema_state, step0,
                                 batches, masks)
        return p, o, e, ms, masks, times

    return chunk


def build_event_chunk_step(grad_fn: Callable, update_fn: Callable, strategy,
                           *, ema_decay: float = 0.0) -> Callable:
    """Fused K-arrival event engine: one ``lax.scan`` dispatch per chunk.

        chunk(params, opt_state, ema, workers [W, ...], aux,
              batches [K, b, ...], rows {name: [K]})
            -> (params, opt_state, ema, workers, aux, losses [K])

    ``workers`` is the stacked per-worker read-parameter pytree (one
    ``[W, ...]`` device tree instead of W host copies); ``aux`` is the
    strategy's device carry (``init_scan_state`` — softsync gradient
    window / staleness ring buffer); ``rows`` is the host-precomputed
    :class:`repro.core.coordination.EventPlan` (``plan.rows()``). Per
    arrival the body gathers the worker's read copy, runs grad_fn, lets
    the strategy aggregate-or-buffer (``on_arrival_scan``), conditionally
    applies the optimizer + EMA (``row["apply"]`` — host-planned, since
    every built-in strategy's verdict is gradient-independent), and
    scatters the fresh params back to the worker's row. The scan replays
    ``run_events``' exact update/staleness sequence because all control
    flow comes from the plan (tests/test_event_scan.py).
    """

    def chunk(params, opt_state, ema_state, workers, aux, batches, rows):
        def body(carry, xs):
            p, o, e, w_stack, ax = carry
            batch, row = xs
            read = jax.tree_util.tree_map(lambda s: s[row["worker"]], w_stack)
            loss, grads = grad_fn(read, batch)
            ax, agg = strategy.on_arrival_scan(ax, grads, row)

            def apply_update(p, o, e):
                out = update_fn(p, o, agg, row["step"])
                p2, o2 = out[0], out[1]
                if ema_decay > 0:
                    e = ema_lib.update(e, p2, ema_decay)
                return p2, o2, e

            p, o, e = jax.lax.cond(row["apply"], apply_update,
                                   lambda p, o, e: (p, o, e), p, o, e)
            # the worker reads the fresh params for its next mini-batch
            w_stack = jax.tree_util.tree_map(
                lambda s, x: s.at[row["worker"]].set(x), w_stack, p)
            return (p, o, e, w_stack, ax), loss

        (p, o, e, w_stack, ax), losses = jax.lax.scan(
            body, (params, opt_state, ema_state, workers, aux),
            (batches, rows))
        return p, o, e, w_stack, ax, losses

    return chunk


def build_eval_step(model) -> Callable:
    def eval_step(params, batch):
        per_tok, _ = model.per_token_loss(params, batch)
        labels = batch["labels"]
        if per_tok.shape[1] != labels.shape[1]:
            pad = per_tok.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], 1)
        valid = (labels >= 0).astype(jnp.float32)
        return jnp.sum(per_tok * valid) / jnp.maximum(jnp.sum(valid), 1.0)

    return eval_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for lowering — shannon/kernels pattern)
# ---------------------------------------------------------------------------


def input_specs(cfg, shape, *, num_workers: int) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every train-step input."""
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        p = cfg.num_prefix_embeds
        batch["tokens"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        batch["prefix_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                      jnp.bfloat16)
    elif cfg.family == "audio":
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {
        "batch": batch,
        "mask": jax.ShapeDtypeStruct((num_workers,), jnp.bool_),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
