"""Recovery supervisor: drive an experiment to completion through faults.

``run_supervised(cfg)`` wraps the Trainer the way a cluster scheduler
wraps a job (docs/robustness.md): build, run, and when the run dies —
an injected :class:`repro.core.faults.Preemption`, a worker-exhaustion
``RuntimeError``, a checkpoint-write ``OSError`` that outlived its
retries, or restored-state corruption — restore the last verified-good
checkpoint (``checkpoint.find_good_step`` walks back past corrupt ones)
and continue, up to ``cfg.faults.max_restarts`` times.

The supervisor owns the :class:`~repro.core.faults.FaultInjector` across
restarts, which is what makes recovery deterministic: faults fire at
most once (a restored run does not replay already-injected faults), and
``injector.resync`` re-applies their *persistent* effects — permanent
deaths, still-active slowdown windows — to each freshly rebuilt Trainer.
When permanent deaths push the live count below the strategy's floor,
the Trainer's own elastic layer (``elastic.plan_rescale``) shrinks the
cluster; the supervisor keeps the rescaled config for later restarts.

Every recovery action lands in the structured log returned as
``TrainResult.recovery_log`` (schema: docs/api.md "Recovery events").
Log entries carry steps/workers/attempt counts only — never wall-clock —
so the same (fault spec, fault seed) yields a bit-identical log.
``recover_times`` collects wall-clock recovery durations out-of-band for
MTTR benchmarking (benchmarks/bench_recovery.py).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

from repro.configs.base import TrainConfig
from repro.core import faults as faults_lib
from repro.core.straggler import LatencyModel
from repro.data.synthetic_lm import SyntheticLMConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.loop import Trainer, TrainResult

# the failure surface a supervisor restart can actually fix: injected
# preemptions, dead-worker exhaustion / corruption (RuntimeError covers
# CheckpointCorruption), and write failures that outlived their retries
RECOVERABLE = (faults_lib.Preemption, RuntimeError, OSError)


def run_supervised(cfg: TrainConfig, *,
                   latency: Optional[LatencyModel] = None,
                   data_cfg: Optional[SyntheticLMConfig] = None,
                   model=None, batch_fn: Optional[Callable] = None,
                   injector: Optional[faults_lib.FaultInjector] = None,
                   max_restarts: Optional[int] = None,
                   recover_times: Optional[List[float]] = None,
                   tracer=None, metrics=None) -> TrainResult:
    """Run ``cfg`` to ``cfg.total_steps``, restarting through failures.

    Mirrors :func:`repro.train.loop.run_experiment`'s keyword surface;
    ``max_restarts`` overrides ``cfg.faults.max_restarts``. Raises the
    final error (after logging a ``give_up`` event) once the restart
    budget is exhausted.
    """
    if injector is None:
        injector = faults_lib.build_injector(
            getattr(cfg, "faults", None), num_steps=cfg.total_steps,
            num_workers=cfg.aggregation.total_workers)
    budget = (getattr(cfg.faults, "max_restarts", 3)
              if max_restarts is None else max_restarts)
    attempts = 0
    resume = False
    crash_t: Optional[float] = None
    while True:
        tr = Trainer(cfg, latency=latency, data_cfg=data_cfg, model=model,
                     batch_fn=batch_fn, injector=injector, tracer=tracer,
                     metrics=metrics)
        if resume:
            good = ckpt_lib.find_good_step(cfg.checkpoint.directory)
            if good is not None:
                tr.restore_checkpoint(good)
            else:
                # nothing verified-good on disk: recovery = fresh start
                tr.init_state()
            if injector is not None:
                injector.record("restore", step=tr.step, attempt=attempts)
        else:
            tr.init_state()
        if injector is not None:
            injector.resync(tr)
        if crash_t is not None and recover_times is not None:
            recover_times.append(time.monotonic() - crash_t)
        crash_t = None
        try:
            return tr.run(max(cfg.total_steps - tr.step, 0))
        except RECOVERABLE as e:
            crash_t = time.monotonic()
            attempts += 1
            cfg = tr.cfg          # keep any elastic rescale the run applied
            if attempts > budget:
                if injector is not None:
                    injector.record("give_up", step=tr.step,
                                    restarts=attempts,
                                    error=type(e).__name__)
                    # budget exhausted: the structured log would otherwise
                    # die with the run — surface it on the exception so
                    # the caller (and the postmortem) still gets it
                    e.recovery_log = list(injector.log)
                raise
            resume = True
