"""Self-healing checkpointing: atomic npz + JSON manifest, keep-k, resume.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, plus <dir>/LATEST
pointing at the newest complete checkpoint. Writes go to a tmp directory
that is fsynced and atomically renamed, so a killed writer never corrupts
state — restart-safe (the paper's cluster reality: preemptions mid-save).

Hardening beyond atomicity (docs/robustness.md):

* every array carries a CRC32 checksum in the manifest, verified on
  restore — a bit-flipped or truncated ``arrays.npz`` is detected, not
  silently loaded;
* writes fsync file contents AND the containing directories before the
  atomic rename commits, so a power loss cannot leave a renamed-but-empty
  checkpoint;
* transient write failures retry with capped, seeded-jittered
  exponential backoff (``CheckpointConfig.write_retries`` /
  ``retry_max_backoff_s`` / ``retry_jitter``; :func:`retry_delays`)
  before the error propagates — the chaos engine's ``ckpt_io`` fault
  injects exactly here;
* restore walks back to the last *verified-good* ``step_*`` dir when the
  requested checkpoint is corrupt, and ``latest_step`` falls back to
  scanning existing step dirs when ``LATEST`` dangles — good checkpoints
  on disk are never stranded by a bad pointer.

The saved tree includes params, optimizer state, EMA, data-pipeline state,
and the aggregation config (N, b, W) — elastic restarts with a different
worker count re-shard and re-scale the lr (repro.train.elastic).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
import zipfile
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointCorruption(RuntimeError):
    """Raised when no verified-good checkpoint could be restored."""


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _checksum(arr: np.ndarray) -> str:
    """CRC32 over dtype, shape and raw bytes (cheap, catches truncation
    and bit flips — not an adversarial-integrity hash)."""
    meta = f"{arr.dtype.str}:{arr.shape}".encode()
    crc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), zlib.crc32(meta))
    return f"crc32:{crc:08x}"


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_attempt(tmp: str, flat: Dict[str, np.ndarray], manifest: Dict,
                   io_check: Optional[Callable[[], None]]) -> None:
    """One durable write of arrays + manifest into ``tmp`` (no rename)."""
    if io_check is not None:
        io_check()                 # chaos engine's ckpt_io injection point
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, default=str)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)


def retry_delays(retries: int, backoff_s: float, *,
                 max_backoff_s: float = 0.25, jitter: float = 0.5,
                 seed: int = 0) -> List[float]:
    """The seeded retry-delay schedule ``save`` sleeps through.

    Exponential backoff capped at ``max_backoff_s``, then scaled by a
    uniform jitter in ``[1, 1 + jitter]`` so a fleet of writers that
    failed together does not retry together (the classic thundering-herd
    fix). The jitter stream is seeded — the same ``seed`` yields the
    identical schedule, which keeps chaos runs replayable.
    """
    rng = np.random.RandomState(seed)
    out = []
    for attempt in range(max(retries, 0)):
        delay = min(backoff_s * (2 ** attempt), max_backoff_s)
        out.append(delay * (1.0 + jitter * float(rng.uniform())))
    return out


def save(directory: str, step: int, tree: Any, metadata: Optional[Dict] = None,
         keep: int = 3, *, retries: int = 3, backoff_s: float = 0.01,
         max_backoff_s: float = 0.25, jitter: float = 0.5,
         backoff_seed: int = 0,
         io_check: Optional[Callable[[], None]] = None,
         on_retry: Optional[Callable[[int, BaseException], None]] = None,
         sleep: Callable[[float], None] = time.sleep) -> str:
    """Write one checkpoint durably and atomically.

    ``io_check`` is called at the start of every write attempt and may
    raise ``OSError`` (fault injection / preflight quota checks). Failed
    attempts retry up to ``retries`` times with jittered exponential
    backoff — capped at ``max_backoff_s``, scaled by a seeded uniform
    jitter in ``[1, 1 + jitter]`` (see :func:`retry_delays`) — with
    ``on_retry(attempt, exc)`` observing each, then re-raise.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "arrays": sorted(flat),
                "checksums": {k: _checksum(v) for k, v in flat.items()},
                **(metadata or {})}
    delays = retry_delays(retries, backoff_s, max_backoff_s=max_backoff_s,
                          jitter=jitter, seed=backoff_seed)
    attempt = 0
    while True:
        tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
        try:
            _write_attempt(tmp, flat, manifest, io_check)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)              # atomic commit
            _fsync_path(directory)
            break
        except OSError as e:
            shutil.rmtree(tmp, ignore_errors=True)
            if attempt >= len(delays):
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(delays[attempt])
            attempt += 1
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _fsync_path(directory)
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # sweep tmp dirs abandoned by writers killed mid-save
    for d in os.listdir(directory):
        if d.startswith(".tmp_ckpt_"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def available_steps(directory: str) -> List[int]:
    """Steps of every complete-looking checkpoint dir (manifest present),
    ascending — what the walk-back fallback iterates over."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in sorted(os.listdir(directory)):
        if d.startswith("step_") and os.path.exists(
                os.path.join(directory, d, "manifest.json")):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                continue
    return out


def latest_step(directory: str) -> Optional[int]:
    """Newest checkpoint step. The ``LATEST`` pointer is a hint; when it
    is missing or dangles (points at a deleted/missing dir) the existing
    ``step_*`` dirs are scanned instead of failing restores while good
    checkpoints exist on disk."""
    latest = os.path.join(directory, "LATEST")
    if os.path.exists(latest):
        with open(latest) as f:
            name = f.read().strip()
        if os.path.isdir(os.path.join(directory, name)):
            try:
                return int(name.split("_")[1])
            except (IndexError, ValueError):
                pass
    steps = available_steps(directory)
    return steps[-1] if steps else None


def _load_verified(directory: str, step: int
                   ) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Load and checksum-verify one checkpoint; raises CheckpointCorruption
    on any integrity failure (unreadable manifest/zip, missing arrays,
    checksum mismatch). Checkpoints written before checksums existed
    verify by array presence only."""
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
    except (OSError, ValueError, json.JSONDecodeError, zipfile.BadZipFile,
            zlib.error, EOFError) as e:
        raise CheckpointCorruption(f"step {step}: {e}") from e
    missing = [k for k in manifest.get("arrays", []) if k not in flat]
    if missing:
        raise CheckpointCorruption(f"step {step}: arrays {missing} listed in "
                                   f"manifest but absent from arrays.npz")
    for k, want in manifest.get("checksums", {}).items():
        if k not in flat:
            raise CheckpointCorruption(f"step {step}: checksummed array "
                                       f"{k!r} missing")
        got = _checksum(flat[k])
        if got != want:
            raise CheckpointCorruption(
                f"step {step}: checksum mismatch for {k!r} "
                f"({got} != manifest {want})")
    return flat, manifest


def verify(directory: str, step: int) -> bool:
    """True iff the checkpoint at ``step`` passes integrity verification."""
    try:
        _load_verified(directory, step)
        return True
    except CheckpointCorruption:
        return False


def find_good_step(directory: str, step: Optional[int] = None
                   ) -> Optional[int]:
    """The newest verified-good step <= ``step`` (or <= latest). Walks
    back over existing ``step_*`` dirs past corrupt ones; None when no
    checkpoint verifies."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    for s in reversed([s for s in available_steps(directory) if s <= step]):
        if verify(directory, s):
            return s
    return None


def read_manifest(directory: str, step: Optional[int] = None) -> Dict:
    """The checkpoint's manifest alone (no array load) — for callers that
    must shape their restore template from saved metadata first."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(directory: str, template: Any, step: Optional[int] = None,
            *, fallback: bool = True) -> Tuple[Any, Dict]:
    """Returns (tree, manifest). template supplies structure/shapes/dtypes.

    Every candidate checkpoint is checksum-verified before use. On
    corruption the restore walks back to the last verified-good
    ``step_*`` dir (``fallback=False`` pins the requested step instead).
    Template mismatches (missing key / wrong shape) always raise — they
    are caller errors, not disk corruption.
    """
    start = step if step is not None else latest_step(directory)
    if start is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    candidates = ([start] if not fallback else
                  list(reversed([s for s in available_steps(directory)
                                 if s <= start])) or [start])
    errors = []
    for s in candidates:
        try:
            flat, manifest = _load_verified(directory, s)
        except CheckpointCorruption as e:
            errors.append(str(e))
            continue
        return _unflatten_like(template, flat), manifest
    raise CheckpointCorruption(
        f"no verified-good checkpoint under {directory} "
        f"(tried steps {candidates}): " + "; ".join(errors))
