"""Fault-tolerant checkpointing: atomic npz + JSON manifest, keep-k, resume.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, plus <dir>/LATEST
pointing at the newest complete checkpoint. Writes go to a tmp directory
that is atomically renamed, so a killed writer never corrupts state —
restart-safe (the paper's cluster reality: preemptions mid-save).

The saved tree includes params, optimizer state, EMA, data-pipeline state,
and the aggregation config (N, b, W) — elastic restarts with a different
worker count re-shard and re-scale the lr (repro.train.elastic).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, tree: Any, metadata: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten_with_paths(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "arrays": sorted(flat), **(metadata or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2, default=str)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def read_manifest(directory: str, step: Optional[int] = None) -> Dict:
    """The checkpoint's manifest alone (no array load) — for callers that
    must shape their restore template from saved metadata first."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(directory: str, template: Any, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Returns (tree, manifest). template supplies structure/shapes/dtypes."""
    manifest = read_manifest(directory, step)
    path = os.path.join(directory, f"step_{manifest['step']:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten_like(template, flat), manifest
