"""Serving steps: prefill and single-token decode with KV caches.

``decode_32k`` / ``long_500k`` cells lower ``serve_step`` — one new token
against a cache of seq_len. long_500k (batch=1) uses sequence-parallel
caches: the KV sequence axis is sharded over the data axis and the softmax
reductions lower to partial-softmax psums (see distributed.sharding).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common


def build_decode_step(model) -> Callable:
    def decode_step(params, token, cache):
        return model.decode_step(params, token, cache)
    return decode_step


def build_prefill(model) -> Callable:
    def prefill(params, batch):
        kwargs = {}
        if "prefix_embeds" in batch:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        if "encoder_frames" in batch:
            kwargs["encoder_frames"] = batch["encoder_frames"]
        return model.prefill(params, batch["tokens"], **kwargs)
    return prefill


def decode_input_specs(model, cfg, shape, cache_dtype=None) -> Dict[str, Any]:
    """ShapeDtypeStructs for (token, cache) at a decode shape cell.

    cache_dtype=jnp.int8 lowers the quantized-KV decode variant."""
    b, s = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: model.init_cache(b, s, cache_dtype))
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": cache_shapes,
    }


def prefill_input_specs(cfg, shape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        p = cfg.num_prefix_embeds
        batch["tokens"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        batch["prefix_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                      jnp.bfloat16)
    elif cfg.family == "audio":
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"batch": batch}


def bucketed_max_len(need: int, floor: int = 8) -> int:
    """Round a cache length up to the next power-of-two bucket.

    The decode caches key jit's shape cache: an exact ``prompt + tokens``
    length retraces on every new prompt, while a power-of-two bucket
    compiles once per bucket (validity masking makes the extra positions
    inert). The serve engine uses the same rule for prompt padding
    (``repro.serve.trace.bucket_for``).
    """
    if need <= 0:
        raise ValueError(f"cache length must be positive (got {need})")
    b = floor
    while b < need:
        b *= 2
    return b


def greedy_generate(model, params, prompt: jnp.ndarray, num_tokens: int,
                    max_len: int, *, bucket: bool = True, **prefill_kwargs):
    """Reference generation loop (tests + examples; not the perf path).

    Prefills by running decode_step over the prompt tokens one by one, then
    greedily decodes ``num_tokens`` more. ``max_len`` is padded to a
    power-of-two bucket (``bucket=False`` restores the exact size) so
    jitted callers compile once per bucket instead of once per prompt
    length.
    """
    b, plen = prompt.shape
    cache = model.init_cache(b, bucketed_max_len(max_len) if bucket
                             else max_len)
    if prefill_kwargs.get("encoder_frames") is not None:
        cache = model.prime_cross_cache(params, cache,
                                        prefill_kwargs["encoder_frames"])
    logits = None
    for i in range(plen):
        logits, cache = model.decode_step(params, prompt[:, i:i + 1], cache)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(prompt.dtype)
    for _ in range(num_tokens):
        out.append(tok)
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(prompt.dtype)
    return jnp.concatenate(out, axis=1)
