"""Hymba-style hybrid: parallel attention + SSM heads in every block.

Each block computes, from the same pre-norm input,
    y = beta_a * attn(x) + beta_s * ssd(x)
(learnable per-block scalars), followed by a SwiGLU FFN. Attention is
sliding-window (cfg.sliding_window) for *all* layers — Hymba keeps only 3
global layers; at the 500k-decode shape the SSM path carries long-range
state, so we adopt window-everywhere (recorded in DESIGN.md). The SSM path
is the multi-head SSD mixer from ``repro.models.mamba``.

Decode caches are O(window) for attention + O(1) SSD state per layer, which
is what makes the ``long_500k`` cell feasible for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mamba, mlp
from repro.models.common import Params


def _ssd_dims(cfg):
    h = cfg.num_heads
    hd = cfg.resolved_head_dim
    return h, hd, cfg.ssm.state_dim


def block_init(key, cfg, dtype) -> Params:
    k1, k2, k3 = common.split_keys(key, 3)
    h, hd, n = _ssd_dims(cfg)
    return {
        "ln1": common.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.gqa_init(k1, cfg, dtype),
        "ssd": mamba.ssd_init(k2, cfg.d_model, h, hd, n, dtype),
        "ssd_out": common.dense_init(jax.random.fold_in(k2, 1), h * hd,
                                     cfg.d_model, dtype),
        "beta_a": jnp.full((), 0.5, jnp.float32),
        "beta_s": jnp.full((), 0.5, jnp.float32),
        "ln2": common.rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.hidden_act, dtype),
    }


def block_apply(p: Params, cfg, x, positions, ssd_state=None, chunked=True):
    h_, hd, n = _ssd_dims(cfg)
    hn = common.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if x.shape[1] > 8192:
        attn_out = attention.gqa_attend_chunked(p["attn"], cfg, hn, positions,
                                                window=cfg.sliding_window)
    else:
        attn_out = attention.gqa_attend(p["attn"], cfg, hn, positions,
                                        window=cfg.sliding_window)
    ssd_y, new_state = mamba.ssd_apply(p["ssd"], hn, h_, hd, n, ssd_state,
                                       chunked=chunked)
    b, s = x.shape[:2]
    ssd_out = common.dense(p["ssd_out"], ssd_y.reshape(b, s, h_ * hd))
    mix = (p["beta_a"] * attn_out.astype(jnp.float32)
           + p["beta_s"] * ssd_out.astype(jnp.float32)).astype(x.dtype)
    x = x + mix
    hn = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + mlp.mlp_apply(p["mlp"], hn, cfg.hidden_act)
    return x, new_state


class HymbaLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = common.dtype_of(cfg.dtype)

    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kB = jax.random.split(key)
        keys = jax.random.split(kB, cfg.num_layers)
        return {
            "embed": common.embed_init(kE, cfg.padded_vocab, cfg.d_model, self.dtype),
            "blocks": jax.vmap(lambda k: block_init(k, cfg, self.dtype))(keys),
            "final_norm": common.rmsnorm_init(cfg.d_model, self.dtype),
        }

    def forward(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = common.embed(params["embed"], tokens).astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(carry, p_l):
            from repro.distributed.context import constrain_layer_params
            h, _ = carry
            p_l = constrain_layer_params(p_l)
            h, _st = block_apply(p_l, cfg, h, positions)
            return (h, 0.0), None

        from repro.models.transformer import _remat_wrap
        body = _remat_wrap(body, cfg.remat)
        (x, _), _ = jax.lax.scan(body, (x, 0.0), params["blocks"])
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x @ params["embed"]["embedding"].T

    def per_token_loss(self, params, batch):
        logits = self.forward(params, batch["tokens"])
        labels = batch["labels"]
        safe = jnp.maximum(labels, 0)
        loss = common.softmax_cross_entropy(logits, safe, self.cfg.vocab_size)
        return jnp.where(labels >= 0, loss, 0.0), jnp.zeros((), jnp.float32)

    # -- decode ---------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        h, hd, n = _ssd_dims(cfg)
        w = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
        return {
            "lens": jnp.zeros((), jnp.int32),
            "attn": [attention.gqa_init_cache(cfg, batch, w, dtype)
                     for _ in range(cfg.num_layers)],
            "ssd": [mamba.ssd_init_state(batch, h, hd, n)
                    for _ in range(cfg.num_layers)],
        }

    def decode_step(self, params, token, cache):
        cfg = self.cfg
        h_, hd, n = _ssd_dims(cfg)
        cache = dict(cache)
        cache_len = cache["lens"]
        x = common.embed(params["embed"], token).astype(self.dtype)
        attn_caches = list(cache["attn"])
        ssd_states = list(cache["ssd"])
        for i in range(cfg.num_layers):
            p = jax.tree_util.tree_map(lambda t: t[i], params["blocks"])
            hn = common.rmsnorm(p["ln1"], x, cfg.norm_eps)
            size = attn_caches[i]["k"].shape[1]
            is_ring = cfg.sliding_window > 0 and size <= cfg.sliding_window
            attn_out, attn_caches[i] = attention.gqa_decode(
                p["attn"], cfg, hn, attn_caches[i], cache_len,
                window=0 if is_ring else cfg.sliding_window,
                write_pos=cache_len % size if is_ring else None)
            ssd_y, ssd_states[i] = mamba.ssd_apply(p["ssd"], hn, h_, hd, n,
                                                   ssd_states[i], chunked=False)
            ssd_out = common.dense(p["ssd_out"], ssd_y.reshape(x.shape[0], 1, -1))
            mix = (p["beta_a"] * attn_out.astype(jnp.float32)
                   + p["beta_s"] * ssd_out.astype(jnp.float32)).astype(x.dtype)
            x = x + mix
            hn = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
            x = x + mlp.mlp_apply(p["mlp"], hn, cfg.hidden_act)
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ params["embed"]["embedding"].T)[:, 0]
        cache.update(attn=attn_caches, ssd=ssd_states, lens=cache_len + 1)
        return logits, cache

    def prefill(self, params, tokens, prefix_embeds=None):
        logits = self.forward(params, tokens)
        return logits[:, -1]


def make(cfg) -> HymbaLM:
    return HymbaLM(cfg)
