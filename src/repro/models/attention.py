"""Attention token mixers: GQA (full / sliding window), MLA, KV-cache decode.

Three execution paths:
  * ``gqa_attend``      — dense masked attention (smoke / short sequences)
  * ``gqa_attend_chunked`` — flash-style KV-chunked scan (long sequences;
    O(S·W) memory for window W, never materializes the full score matrix)
  * ``gqa_decode``      — single-token decode against a KV cache; works with
    batch-sharded or sequence-sharded (SP) caches — the softmax reductions
    lower to psums under GSPMD when the cache's S axis is mesh-sharded.

MLA (DeepSeek-V2) is implemented in decomposed form and caches only the
compressed latent + rope key (its memory win) at decode time.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params


# ---------------------------------------------------------------------------
# GQA projection parameters
# ---------------------------------------------------------------------------


def gqa_init(key, cfg, dtype=jnp.float32) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = common.split_keys(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d, h * hd, dtype, bias=cfg.use_bias),
        "wk": common.dense_init(ks[1], d, kv * hd, dtype, bias=cfg.use_bias),
        "wv": common.dense_init(ks[2], d, kv * hd, dtype, bias=cfg.use_bias),
        "wo": common.dense_init(ks[3], h * hd, d, dtype, bias=cfg.use_bias,
                                std=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_init(hd, dtype)
        p["k_norm"] = common.rmsnorm_init(hd, dtype)
    return p


def _project_qkv(params: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = common.dense(params["wq"], x).reshape(b, s, h, hd)
    k = common.dense(params["wk"], x).reshape(b, s, kv, hd)
    v = common.dense(params["wv"], x).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        # qk-norm scales are replicated but applied to head-SHARDED q/k
        # under manual TP: tp.shared_param assembles their full gradient
        # from the per-shard (local-heads-only) partial cotangents
        from repro.distributed import tp
        q = common.rmsnorm(tp.shared_param(params["q_norm"], "attn"), q,
                           cfg.norm_eps)
        k = common.rmsnorm(tp.shared_param(params["k_norm"], "attn"), k,
                           cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _window_ok(diff: jnp.ndarray, window) -> jnp.ndarray:
    """True where `diff` (q_pos - k_pos) is within the lookback window.

    ``window`` may be a Python int or a traced scalar (per-layer windows fed
    through ``lax.scan`` — gemma3's 5:1 local:global pattern). window<=0
    means unlimited.
    """
    window = jnp.asarray(window, jnp.int32)
    return jnp.where(window > 0, diff < window, True)


def make_attention_mask(s_q: int, s_kv: int, *, causal: bool = True,
                        window=0, q_offset: int = 0) -> jnp.ndarray:
    """[s_q, s_kv] boolean mask. window>0 limits lookback to `window` tokens."""
    qpos = jnp.arange(s_q) + q_offset
    kpos = jnp.arange(s_kv)
    diff = qpos[:, None] - kpos[None, :]
    mask = diff >= 0 if causal else jnp.ones((s_q, s_kv), bool)
    return mask & _window_ok(diff, window)


# ---------------------------------------------------------------------------
# Dense path
# ---------------------------------------------------------------------------


def gqa_attend(params: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray,
               *, window=0) -> jnp.ndarray:
    """Full-sequence attention. x: [B, S, d] -> [B, S, d]."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    k = _expand_kv(k, cfg.q_per_kv)
    v = _expand_kv(v, cfg.q_per_kv)
    hd = cfg.resolved_head_dim
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    scores = common.softcap(scores, cfg.attn_logit_softcap)
    mask = make_attention_mask(s, s, window=window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return common.dense(params["wo"], out.reshape(b, s, -1))


# ---------------------------------------------------------------------------
# Chunked (flash-style) path: scan over KV chunks with running softmax stats
# ---------------------------------------------------------------------------


def chunked_attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window=0, softcap: float = 0.0,
                           q_chunk: int = 2048,
                           kv_chunk: int = 2048) -> jnp.ndarray:
    """Blocked attention on projected q/k/v [B, S, H, D] (KV already
    head-expanded): O(q_chunk·kv_chunk) live scores instead of O(S²).

    Scans query chunks (outer) and KV chunks (inner) keeping running
    (max, sum, weighted-V) accumulators — the standard online-softmax
    recurrence; this is the jnp twin of the Pallas flash kernel in
    ``repro.kernels.flash_attention``. Used by GQA (rotary), whisper
    (learned positions), and long cross-attention.
    """
    b, s, h, hd = q.shape
    s_kv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s_kv)
    nq = -(-s // q_chunk)
    nk = -(-s_kv // kv_chunk)
    pad_q = nq * q_chunk - s
    pad_k = nk * kv_chunk - s_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    from repro.distributed.context import constrain_dims
    qs = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 3, 2, 4)  # [nq,B,H,qc,hd]
    ks = k.reshape(b, nk, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    chunk_kinds = (None, "batch", "heads", None, None)
    qs = constrain_dims(qs, chunk_kinds)
    ks = constrain_dims(ks, chunk_kinds)
    vs = constrain_dims(vs, chunk_kinds)

    def q_step(_, qi_q):
        qi, qc = qi_q
        q_off = qi * q_chunk

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kc, vc = ki_kv
            scores = jnp.einsum("bhqd,bhkd->bhqk", qc, kc).astype(jnp.float32) * scale
            scores = common.softcap(scores, softcap)
            qpos = q_off + jnp.arange(q_chunk)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            diff = qpos[:, None] - kpos[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = (diff >= 0) & _window_ok(diff, window)
            mask = mask & (kpos < s_kv)[None, :]        # kv padding
            scores = jnp.where(mask[None, None], scores, -1e30)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, constrain_dims(out.astype(qc.dtype),
                                    ("batch", "heads", None, None))

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))  # [nq,B,H,qc,hd]
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_chunk, h, hd)[:, :s]


def gqa_attend_chunked(params: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                       *, window=0, q_chunk: int = 2048,
                       kv_chunk: int = 2048) -> jnp.ndarray:
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    k = _expand_kv(k, cfg.q_per_kv)
    v = _expand_kv(v, cfg.q_per_kv)
    out = chunked_attention_core(q, k, v, causal=True, window=window,
                                 softcap=cfg.attn_logit_softcap,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk)
    return common.dense(params["wo"], out.reshape(b, s, -1))


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------


def gqa_init_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    """KV cache. dtype=jnp.int8 selects the quantized layout: int8 payload
    + per-(position, head) f16 scales (KIVI/KVQuant-style per-token
    scaling) — halves decode's dominant HBM term vs bf16 at <1% logit
    error (tests/test_quant_cache.py)."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if dtype == jnp.int8:
        return {
            "k": jnp.zeros((batch, max_len, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, kv), jnp.float16),
            "v_scale": jnp.zeros((batch, max_len, kv), jnp.float16),
        }
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def _quantize_kv(x: jnp.ndarray):
    """x: [B, 1, kv, hd] -> (int8 payload, f16 per-(pos,head) scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def gqa_decode(params: Params, cfg, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cache_len: jnp.ndarray, *, window=0, write_pos=None,
               update_cache: bool = True) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode. x: [B, 1, d]; cache k/v: [B, S, kv, hd].

    ``cache_len`` is the *true* sequence position of the new token (drives
    RoPE and validity). ``write_pos`` is where its K/V lands in the buffer —
    defaults to cache_len; pass ``cache_len % size`` for ring-buffer local
    (sliding-window) caches, in which case every buffer slot is valid once
    wrapped. The score computation is written with explicit reductions so a
    sequence-sharded cache lowers to partial-softmax psums (sequence
    parallelism).
    """
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if write_pos is None:
        write_pos = cache_len
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, pos)
    quantized = cache["k"].dtype == jnp.int8
    if update_cache:
        new_cache = dict(cache)
        if quantized:
            kq, ks = _quantize_kv(k_new)
            vq, vs = _quantize_kv(v_new)
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], kq, (0, write_pos, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], vq, (0, write_pos, 0, 0))
            new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, write_pos, 0))
            new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, write_pos, 0))
        else:
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, write_pos, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, write_pos, 0, 0))
        cache = new_cache
    if quantized:
        k = _dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
        v = _dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
    else:
        k, v = cache["k"], cache["v"]
    s = k.shape[1]
    q = q.reshape(b, h, hd)
    # grouped: [B, kv, q_per_kv, hd]
    qg = q.reshape(b, kv, cfg.q_per_kv, hd)
    scores = jnp.einsum("bgqd,bsgd->bgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    scores = common.softcap(scores, cfg.attn_logit_softcap)
    kpos = jnp.arange(s)
    valid = (kpos <= cache_len) & _window_ok(cache_len - kpos, window)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqs,bsgd->bgqd", probs.astype(v.dtype), v)
    out = out.reshape(b, 1, h * hd)
    return common.dense(params["wo"], out), cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    m = cfg.mla
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    ks = common.split_keys(key, 6)
    p = {
        # query: full-rank (q_lora_rank==0) or low-rank
        "wq": common.dense_init(ks[0], d, h * qk_dim, dtype),
        # compressed kv latent + shared rope key
        "wkv_a": common.dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_dim, dtype),
        "kv_norm": common.rmsnorm_init(m.kv_lora_rank, dtype),
        "wkv_b": common.dense_init(ks[2], m.kv_lora_rank,
                                   h * (m.qk_nope_dim + m.v_head_dim), dtype),
        "wo": common.dense_init(ks[3], h * m.v_head_dim, d, dtype,
                                std=1.0 / math.sqrt(h * m.v_head_dim)),
    }
    if m.q_lora_rank:
        p["wq_a"] = common.dense_init(ks[4], d, m.q_lora_rank, dtype)
        p["q_norm"] = common.rmsnorm_init(m.q_lora_rank, dtype)
        p["wq_b"] = common.dense_init(ks[5], m.q_lora_rank, h * qk_dim, dtype)
        del p["wq"]
    return p


def _mla_qkv(params: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray):
    b, s, _ = x.shape
    h = cfg.num_heads
    m = cfg.mla
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    if "wq_a" in params:
        q = common.dense(params["wq_b"],
                         common.rmsnorm(params["q_norm"],
                                        common.dense(params["wq_a"], x), cfg.norm_eps))
    else:
        q = common.dense(params["wq"], x)
    q = q.reshape(b, s, h, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = common.dense(params["wkv_a"], x)                       # [B,S,rank+rope]
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = common.rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = common.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,rope]
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params: Params, cfg, c_kv: jnp.ndarray):
    b, s, _ = c_kv.shape
    h = cfg.num_heads
    m = cfg.mla
    kv = common.dense(params["wkv_b"], c_kv).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    return k_nope, v


def mla_attend(params: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = x.shape
    h = cfg.num_heads
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope, v = _mla_expand_kv(params, cfg, c_kv)
    if s > 8192:
        # long sequences: fold (nope ‖ rope) into one head dim and run the
        # blocked online-softmax core — the dense path materializes a full
        # [S, S] score matrix (observed 4.3 GB at 32k prefill). v is padded
        # to the qk width and sliced back (the core is square in D).
        qk = jnp.concatenate([q_nope, q_rope], axis=-1)        # [B,S,H,nope+rope]
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_dim))], -1)
        d_qk = m.qk_nope_dim + m.qk_rope_dim
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, d_qk - m.v_head_dim)))
        out = chunked_attention_core(qk, kk, v_pad, causal=True)
        out = out[..., :m.v_head_dim]
        return common.dense(params["wo"], out.reshape(b, s, -1))
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkld->bhqk", q_rope,
                           jnp.broadcast_to(k_rope, (b, s, 1, m.qk_rope_dim)))
              ).astype(jnp.float32) * scale
    mask = make_attention_mask(s, s)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return common.dense(params["wo"], out.reshape(b, s, -1))


def mla_init_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }


def mla_decode(params: Params, cfg, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cache_len: jnp.ndarray,
               update_cache: bool = True) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """MLA decode caching only (c_kv, k_rope) — the latent-cache memory win."""
    b = x.shape[0]
    h = cfg.num_heads
    m = cfg.mla
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _mla_qkv(params, cfg, x, pos)
    if update_cache:
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, cache_len, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], kr_new[:, :, 0].astype(cache["k_rope"].dtype),
                (0, cache_len, 0)),
        }
    c_kv, k_rope = cache["c_kv"], cache["k_rope"]
    s = c_kv.shape[1]
    # absorb wkv_b into the query (decode-time trick): score_nope =
    # (q_nope @ Wb_k^T) @ c_kv^T  — avoids expanding K per head over S.
    wkv_b = params["wkv_b"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    wb_k = wkv_b[..., :m.qk_nope_dim]                              # [rank,h,nope]
    wb_v = wkv_b[..., m.qk_nope_dim:]                              # [rank,h,v]
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, wb_k)             # [B,1,h,rank]
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, c_kv)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope)).astype(jnp.float32) * scale
    valid = jnp.arange(s) <= cache_len
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs.astype(c_kv.dtype), c_kv)  # latent ctx
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, wb_v).reshape(b, 1, -1)
    return common.dense(params["wo"], out), cache
