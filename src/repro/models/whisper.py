"""Whisper-style encoder-decoder backbone (audio frontend STUBBED).

Per the assignment, the conv frontend is a stub: ``input_specs()`` supplies
precomputed frame embeddings [B, T_enc, d] (T_enc = cfg.encoder_seq_len,
whisper's fixed 1500). The transformer backbone is real: bidirectional
encoder, causal decoder with cross-attention, pre-LN, GELU FFN, learned
positional embeddings, tied decoder embedding/output.

Decode caches decoder self-attn K/V plus per-layer cross-attn K/V computed
once from the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mlp
from repro.models.common import Params


def _enc_block_init(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": common.layernorm_init(cfg.d_model, dtype),
        "attn": attention.gqa_init(k1, cfg, dtype),
        "ln2": common.layernorm_init(cfg.d_model, dtype),
        "mlp": mlp.mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", dtype, bias=True),
    }


def _dec_block_init(key, cfg, dtype) -> Params:
    k1, k2, k3 = common.split_keys(key, 3)
    return {
        "ln1": common.layernorm_init(cfg.d_model, dtype),
        "attn": attention.gqa_init(k1, cfg, dtype),
        "ln_x": common.layernorm_init(cfg.d_model, dtype),
        "xattn": attention.gqa_init(k2, cfg, dtype),
        "ln2": common.layernorm_init(cfg.d_model, dtype),
        "mlp": mlp.mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", dtype, bias=True),
    }


CHUNK_THRESHOLD = 8192


def _self_attend(p, cfg, x, *, causal):
    """Non-rotary MHA (whisper uses absolute learned positions); switches
    to the blocked online-softmax core for long sequences."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = common.dense(p["wq"], x).reshape(b, s, h, hd)
    k = common.dense(p["wk"], x).reshape(b, s, kv, hd)
    v = common.dense(p["wv"], x).reshape(b, s, kv, hd)
    k = attention._expand_kv(k, cfg.q_per_kv)
    v = attention._expand_kv(v, cfg.q_per_kv)
    if s > CHUNK_THRESHOLD:
        out = attention.chunked_attention_core(q, k, v, causal=causal)
        return common.dense(p["wo"], out.reshape(b, s, -1))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / (hd ** 0.5)
    if causal:
        mask = attention.make_attention_mask(s, s)
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
    return common.dense(p["wo"], out)


def _cross_attend(p, cfg, x, enc_k, enc_v):
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = common.dense(p["wq"], x).reshape(b, s, h, hd)
    if s > CHUNK_THRESHOLD:
        out = attention.chunked_attention_core(q, enc_k, enc_v, causal=False)
        return common.dense(p["wo"], out.reshape(b, s, -1))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, enc_k).astype(jnp.float32) / (hd ** 0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, enc_v).reshape(b, s, -1)
    return common.dense(p["wo"], out)


def _cross_kv(p, cfg, enc_out):
    b, t, _ = enc_out.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k = common.dense(p["wk"], enc_out).reshape(b, t, kv, hd)
    v = common.dense(p["wv"], enc_out).reshape(b, t, kv, hd)
    return attention._expand_kv(k, cfg.q_per_kv), attention._expand_kv(v, cfg.q_per_kv)


class WhisperModel:
    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = common.dtype_of(cfg.dtype)

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = common.split_keys(key, 5)
        enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.num_layers)
        return {
            "embed": common.embed_init(ks[2], cfg.padded_vocab, cfg.d_model, self.dtype),
            "pos_dec": common.trunc_normal(ks[3], (cfg.max_seq_len, cfg.d_model),
                                           0.01, self.dtype),
            "pos_enc": common.trunc_normal(ks[4], (cfg.encoder_seq_len, cfg.d_model),
                                           0.01, self.dtype),
            "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, self.dtype))(enc_keys),
            "enc_ln": common.layernorm_init(cfg.d_model, self.dtype),
            "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, self.dtype))(dec_keys),
            "dec_ln": common.layernorm_init(cfg.d_model, self.dtype),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(self.dtype) + params["pos_enc"][None, :frames.shape[1]]

        def body(h, p_l):
            from repro.distributed.context import (constrain_activations,
                                                   constrain_layer_params)
            p_l = constrain_layer_params(p_l)
            a = _self_attend(p_l["attn"], cfg,
                             common.layernorm(p_l["ln1"], h, 1e-5), causal=False)
            h = h + a
            m = mlp.mlp_apply(p_l["mlp"], common.layernorm(p_l["ln2"], h, 1e-5), "gelu")
            return constrain_activations(h + m), None

        from repro.models.transformer import _remat_wrap
        body = _remat_wrap(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return common.layernorm(params["enc_ln"], x, 1e-5)

    def decode_stack(self, params, tokens, enc_out):
        cfg = self.cfg
        b, s = tokens.shape
        x = common.embed(params["embed"], tokens).astype(self.dtype)
        x = x + params["pos_dec"][None, :s]

        def body(h, p_l):
            from repro.distributed.context import (constrain_activations,
                                                   constrain_layer_params)
            p_l = constrain_layer_params(p_l)
            a = _self_attend(p_l["attn"], cfg,
                             common.layernorm(p_l["ln1"], h, 1e-5), causal=True)
            h = h + a
            ek, ev = _cross_kv(p_l["xattn"], cfg, enc_out)
            c = _cross_attend(p_l["xattn"], cfg,
                              common.layernorm(p_l["ln_x"], h, 1e-5), ek, ev)
            h = h + c
            m = mlp.mlp_apply(p_l["mlp"], common.layernorm(p_l["ln2"], h, 1e-5), "gelu")
            return constrain_activations(h + m), None

        from repro.models.transformer import _remat_wrap
        body = _remat_wrap(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = common.layernorm(params["dec_ln"], x, 1e-5)
        return x @ params["embed"]["embedding"].T

    def forward(self, params, tokens, encoder_frames=None, prefix_embeds=None):
        frames = encoder_frames if encoder_frames is not None else prefix_embeds
        enc_out = self.encode(params, frames)
        return self.decode_stack(params, tokens, enc_out)

    def per_token_loss(self, params, batch):
        labels = batch["labels"]
        logits = self.forward(params, batch["tokens"],
                              encoder_frames=batch["encoder_frames"])
        safe = jnp.maximum(labels, 0)
        loss = common.softmax_cross_entropy(logits, safe, self.cfg.vocab_size)
        return jnp.where(labels >= 0, loss, 0.0), jnp.zeros((), jnp.float32)

    # -- decode ---------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        h, hd = cfg.num_heads, cfg.resolved_head_dim
        t = cfg.encoder_seq_len
        return {
            "lens": jnp.zeros((), jnp.int32),
            "self": [attention.gqa_init_cache(cfg, batch, max_len, dtype)
                     for _ in range(cfg.num_layers)],
            "cross_k": [jnp.zeros((batch, t, h, hd), dtype)
                        for _ in range(cfg.num_layers)],
            "cross_v": [jnp.zeros((batch, t, h, hd), dtype)
                        for _ in range(cfg.num_layers)],
        }

    def prime_cross_cache(self, params, cache, frames):
        """Populate per-layer cross K/V from encoder output (prefill side)."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        cache = dict(cache)
        ck, cv = [], []
        for i in range(cfg.num_layers):
            p = jax.tree_util.tree_map(lambda t_: t_[i], params["dec_blocks"])
            k, v = _cross_kv(p["xattn"], cfg, enc_out)
            ck.append(k.astype(cache["cross_k"][i].dtype))
            cv.append(v.astype(cache["cross_v"][i].dtype))
        cache.update(cross_k=ck, cross_v=cv)
        return cache

    def decode_step(self, params, token, cache):
        cfg = self.cfg
        cache = dict(cache)
        cache_len = cache["lens"]
        b = token.shape[0]
        x = common.embed(params["embed"], token).astype(self.dtype)
        pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], cache_len, 1)
        x = x + pos[None]
        selfc = list(cache["self"])
        for i in range(cfg.num_layers):
            p = jax.tree_util.tree_map(lambda t_: t_[i], params["dec_blocks"])
            hn = common.layernorm(p["ln1"], x, 1e-5)
            # non-rotary decode: reuse gqa_decode but bypass rope by
            # projecting manually
            h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
            q = common.dense(p["attn"]["wq"], hn).reshape(b, 1, h, hd)
            k_new = common.dense(p["attn"]["wk"], hn).reshape(b, 1, kv, hd)
            v_new = common.dense(p["attn"]["wv"], hn).reshape(b, 1, kv, hd)
            c = selfc[i]
            c = {
                "k": jax.lax.dynamic_update_slice(
                    c["k"], k_new.astype(c["k"].dtype), (0, cache_len, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    c["v"], v_new.astype(c["v"].dtype), (0, cache_len, 0, 0)),
            }
            selfc[i] = c
            qg = q.reshape(b, kv, cfg.q_per_kv, hd)
            scores = jnp.einsum("bgqd,bsgd->bgqs", qg, c["k"]).astype(jnp.float32) / (hd ** 0.5)
            valid = jnp.arange(c["k"].shape[1]) <= cache_len
            scores = jnp.where(valid[None, None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            att = jnp.einsum("bgqs,bsgd->bgqd", probs.astype(c["v"].dtype), c["v"])
            x = x + common.dense(p["attn"]["wo"], att.reshape(b, 1, -1))
            # cross attention against the primed cache
            hn = common.layernorm(p["ln_x"], x, 1e-5)
            xo = _cross_attend(p["xattn"], cfg, hn, cache["cross_k"][i],
                               cache["cross_v"][i])
            x = x + xo
            hn = common.layernorm(p["ln2"], x, 1e-5)
            x = x + mlp.mlp_apply(p["mlp"], hn, "gelu")
        x = common.layernorm(params["dec_ln"], x, 1e-5)
        logits = (x @ params["embed"]["embedding"].T)[:, 0]
        cache.update(self=selfc, lens=cache_len + 1)
        return logits, cache

    def prefill(self, params, tokens, encoder_frames=None, prefix_embeds=None):
        logits = self.forward(params, tokens, encoder_frames=encoder_frames,
                              prefix_embeds=prefix_embeds)
        return logits[:, -1]


def make(cfg) -> WhisperModel:
    return WhisperModel(cfg)
