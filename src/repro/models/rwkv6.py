"""RWKV-6 "Finch": data-dependent-decay linear attention + channel mix.

Per head (head_dim = D), with receptance r_t, key k_t, value v_t, bonus u,
and *data-dependent* decay w_t = exp(-exp(ŵ_t)):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T            (state: [D, D])
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Three execution paths: a step ``lax.scan`` (oracle / decode), a chunked
parallel form (training; the jnp twin of the Pallas kernel in
``repro.kernels.rwkv6_scan``), and O(1)-state decode. Token-shift and the
low-rank data-dependent parameterizations follow the paper (arXiv:2404.05892),
with the LoRA ranks reduced to their structural essence.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params


def _lora_init(key, d: int, rank: int, out: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "a": common.trunc_normal(k1, (d, rank), 1.0 / d ** 0.5, dtype),
        "b": common.trunc_normal(k2, (rank, out), 1.0 / rank ** 0.5, dtype),
    }


def _lora(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.tanh(x @ p["a"]) @ p["b"]


def time_mix_init(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = common.split_keys(key, 10)
    return {
        "mu": {name: jnp.full((d,), 0.5, dtype) for name in ("r", "k", "v", "w", "g")},
        "w_lora": _lora_init(ks[0], d, 64, d, dtype),
        "w_base": jnp.full((d,), -6.0, dtype),       # decay bias (slow default)
        "wr": common.dense_init(ks[1], d, d, dtype),
        "wk": common.dense_init(ks[2], d, d, dtype),
        "wv": common.dense_init(ks[3], d, d, dtype),
        "wg": common.dense_init(ks[4], d, d, dtype),
        "wo": common.dense_init(ks[5], d, d, dtype),
        "u": common.trunc_normal(ks[6], (h, hd), 0.5, dtype),  # per-head bonus
        "ln_x": common.layernorm_init(d, dtype),
    }


def _token_shift(x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """shift(x)_t = x_{t-1}; x_prev is the seed for t=0. x: [B,S,d]."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(mu: jnp.ndarray, x: jnp.ndarray, shifted: jnp.ndarray) -> jnp.ndarray:
    return x + (shifted - x) * mu


def time_mix_project(params: Params, cfg, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Projections + data-dependent decays. Returns (r,k,v,g,w) [B,S,H,D]."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    sx = _token_shift(x, x_prev)
    xr = _mix(params["mu"]["r"], x, sx)
    xk = _mix(params["mu"]["k"], x, sx)
    xv = _mix(params["mu"]["v"], x, sx)
    xw = _mix(params["mu"]["w"], x, sx)
    xg = _mix(params["mu"]["g"], x, sx)
    r = common.dense(params["wr"], xr).reshape(b, s, h, hd)
    k = common.dense(params["wk"], xk).reshape(b, s, h, hd)
    v = common.dense(params["wv"], xv).reshape(b, s, h, hd)
    g = jax.nn.silu(common.dense(params["wg"], xg))
    # data-dependent decay in (0,1): w = exp(-exp(w_base + lora(xw))).
    # w_log is clamped so per-step |log w| <= 5: keeps the chunked form's
    # exp(-cumsum(log w)) factor finite in f32 for chunk <= 16 (max e^80).
    w_log = params["w_base"].astype(jnp.float32) + _lora(params["w_lora"], xw).astype(jnp.float32)
    w_log = jnp.clip(w_log, -8.0, 1.6)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, s, h, hd)
    return r, k, v, g, w


def wkv_scan(r, k, v, w, u, state=None):
    """Sequential oracle. r,k,v,w: [B,S,H,D]; u: [H,D]; state: [B,H,D,D].

    Returns (out [B,S,H,D], final_state). Computed in f32.
    """
    b, s, h, d = r.shape
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    if state is None:
        state = jnp.zeros((b, h, d, d), f32)

    def step(st, inp):
        rt, kt, vt, wt = inp                                  # [B,H,D]
        kv = kt[..., :, None] * vt[..., None, :]              # [B,H,D,D]
        out = jnp.einsum("bhd,bhde->bhe", rt, st + u[None, :, :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))  # [S,B,H,D]
    state, outs = jax.lax.scan(step, state, xs)
    return outs.transpose(1, 0, 2, 3), state


def wkv_chunked(r, k, v, w, u, state=None, chunk: int = 16):
    """Chunked-parallel wkv6: intra-chunk attention form + inter-chunk state.

    Within a chunk of length C, with cumulative decays A_t = prod_{i<=t} w_i:
      contribution of j<t:  r_t · diag(A_t / A_j) · (k_j v_j^T)
      j == t (bonus):       r_t · diag(u) k_t v_t^T
      carried state:        r_t · diag(A_t_exclusive) · S_in
    This is the jnp oracle-equivalent of the Pallas kernel.
    """
    b, s, h, d = r.shape
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    if state is None:
        state = jnp.zeros((b, h, d, d), f32)
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    rs = r.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)   # [n,B,H,C,D]
    ks = k.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)
    ws = w.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)

    def chunk_step(st, inp):
        rc, kc, vc, wc = inp                                     # [B,H,C,D]
        logw = jnp.log(jnp.maximum(wc, 1e-30))
        acc = jnp.cumsum(logw, axis=2)                           # inclusive
        acc_ex = acc - logw                                      # exclusive
        a_in = jnp.exp(acc_ex)                                   # decay to state
        # intra-chunk: scores[t,j] = sum_d r_t[d] k_j[d] exp(acc_ex[t]-acc[j])
        ri = rc * a_in                                           # r_t ⊙ A_t^-excl... (factored)
        kj = kc * jnp.exp(-acc)
        scores = jnp.einsum("bhtd,bhjd->bhtj", ri, kj)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)       # strictly lower
        scores = jnp.where(tri[None, None], scores, 0.0)
        bonus = jnp.einsum("bhtd,bhtd->bht", rc * u[None, :, None, :], kc)
        out = jnp.einsum("bhtj,bhjd->bhtd", scores, vc)
        out = out + bonus[..., None] * vc
        out = out + jnp.einsum("bhtd,bhde->bhte", ri, st)
        # state update: S_out = diag(A_C) S_in + sum_j diag(A_C/A_j) k_j v_j^T
        a_all = jnp.exp(acc[:, :, -1:, :])                       # [B,H,1,D]
        k_dec = kc * jnp.exp(acc[:, :, -1:, :] - acc)
        st = a_all[:, :, 0, :, None] * st + jnp.einsum("bhjd,bhje->bhde", k_dec, vc)
        return st, out

    state, outs = jax.lax.scan(chunk_step, state, (rs, ks, vs, ws))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, n * chunk, h, d)[:, :s]
    return out, state


def time_mix_apply(params: Params, cfg, x: jnp.ndarray, x_prev: jnp.ndarray,
                   state=None, chunked: bool = True):
    """Full RWKV6 time-mix block (no residual). Returns (out, (x_last, state))."""
    b, s, d = x.shape
    r, k, v, g, w = time_mix_project(params, cfg, x, x_prev)
    u = params["u"].astype(jnp.float32)
    if chunked and s > 1:
        out, state = wkv_chunked(r, k, v, w, u, state)
    else:
        out, state = wkv_scan(r, k, v, w, u, state)
    out = out.reshape(b, s, d).astype(x.dtype)
    out = common.layernorm(params["ln_x"], out, 1e-5) * g
    out = common.dense(params["wo"], out)
    return out, (x[:, -1, :], state)


def channel_mix_init(key, cfg, dtype=jnp.float32) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = common.split_keys(key, 2)
    return {
        "mu": {name: jnp.full((d,), 0.5, dtype) for name in ("k", "r")},
        "wk": common.dense_init(ks[0], d, f, dtype),
        "wv": common.dense_init(ks[1], f, d, dtype),
        "wr": common.dense_init(jax.random.fold_in(key, 7), d, d, dtype),
    }


def channel_mix_apply(params: Params, x: jnp.ndarray, x_prev: jnp.ndarray):
    sx = _token_shift(x, x_prev)
    xk = _mix(params["mu"]["k"], x, sx)
    xr = _mix(params["mu"]["r"], x, sx)
    k = jnp.square(jax.nn.relu(common.dense(params["wk"], xk)))
    r = jax.nn.sigmoid(common.dense(params["wr"], xr))
    return r * common.dense(params["wv"], k), x[:, -1, :]


def rwkv_block_init(key, cfg, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = common.split_keys(key, 4)
    return {
        "ln1": common.layernorm_init(cfg.d_model, dtype),
        "att": time_mix_init(k1, cfg, dtype),
        "ln2": common.layernorm_init(cfg.d_model, dtype),
        "ffn": channel_mix_init(k2, cfg, dtype),
    }


def rwkv_block_apply(params: Params, cfg, x: jnp.ndarray, block_state, chunked=True):
    """block_state: dict(att_x, att_s, ffn_x). Returns (x, new_state)."""
    h = common.layernorm(params["ln1"], x, 1e-5)
    att, (ax, astate) = time_mix_apply(params["att"], cfg, h,
                                       block_state["att_x"], block_state["att_s"],
                                       chunked=chunked)
    x = x + att
    h = common.layernorm(params["ln2"], x, 1e-5)
    ffn, fx = channel_mix_apply(params["ffn"], h, block_state["ffn_x"])
    x = x + ffn
    return x, {"att_x": ax, "att_s": astate, "ffn_x": fx}


def rwkv_init_block_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "att_x": jnp.zeros((batch, d), dtype),
        "att_s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "ffn_x": jnp.zeros((batch, d), dtype),
    }
