"""Model zoo: unified transformer (dense/moe/vlm), hymba, rwkv6, whisper, CNN."""
from repro.models import registry

get_model = registry.get_model
param_count = registry.param_count
