"""Shared building blocks: inits, norms, rope, dense layers, losses.

All models in this repo are pure functions over pytrees of jnp arrays:
``init(key, cfg) -> params`` and ``apply(params, cfg, ...) -> out``. No flax —
the parameterization is explicit so sharding rules can be attached by path.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, std, dtype=jnp.float32):
    # 2-sigma truncated normal, the LM-standard init
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, *, bias: bool = False,
               std: Optional[float] = None) -> Params:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": trunc_normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype=jnp.float32, std: float = 0.02) -> Params:
    return {"embedding": trunc_normal(key, (vocab, d), std, dtype)}


def embed(params: Params, ids: jnp.ndarray) -> jnp.ndarray:
    from repro.distributed import tp
    axis = tp.vocab_active()
    if axis is not None:              # manual-TP vocab-sharded table
        return tp.sharded_embed(params["embedding"], ids, axis)
    return jnp.take(params["embedding"], ids, axis=0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
        "swiglu": jax.nn.silu,  # gate activation inside SwiGLU
    }[name]


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          valid_vocab: Optional[int] = None) -> jnp.ndarray:
    """Per-position CE, numerically stable, vocab-sharding friendly.

    Written as ``lse - label_logit`` with explicit reductions over the vocab
    axis so that GSPMD keeps vocab-sharded logits sharded (the reductions
    lower to small psums instead of an all-gather of the logits). Under the
    SPMD engine's manual TP context the logits arrive as the LOCAL vocab
    slice and the reductions are explicit collectives
    (``tp.sharded_cross_entropy``).
    """
    from repro.distributed import tp
    axis = tp.vocab_active()
    if axis is not None:
        return tp.sharded_cross_entropy(logits, labels, valid_vocab, axis)
    logits = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        pad_mask = jnp.arange(logits.shape[-1]) >= valid_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - label_logit


def chunked_cross_entropy(x: jnp.ndarray, out_embed: jnp.ndarray, labels: jnp.ndarray,
                          valid_vocab: int, chunk: int = 4096) -> jnp.ndarray:
    """CE over huge vocabs without materializing full [T, V] logits.

    Scans over token chunks; each chunk's logits live only inside the scan
    body (rematerialized in backward). x: [T, d]; out_embed: [d, V] (possibly
    vocab-sharded); labels: [T]. Returns per-token loss [T].
    """
    t = x.shape[0]
    n = max(1, -(-t // chunk))
    pad = n * chunk - t
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], 0)
        labels = jnp.concatenate([labels, jnp.zeros((pad,), labels.dtype)], 0)
    xs = x.reshape(n, chunk, x.shape[1])
    ls = labels.reshape(n, chunk)

    @jax.checkpoint
    def body(carry, inp):
        xc, lc = inp
        logits = xc @ out_embed
        return carry, softmax_cross_entropy(logits, lc, valid_vocab)

    _, losses = jax.lax.scan(body, (), (xs, ls))
    losses = losses.reshape(n * chunk)
    return losses[:t]


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
