"""The paper's §2.1 / Appendix A.1 model: 4-layer 3x3 CNN with max-pooling
and weight normalization in every layer, for the staleness experiments.

Weight norm (Salimans & Kingma): w = g * v / ||v||, per output channel.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params


def _wn_conv_init(key, k: int, c_in: int, c_out: int) -> Params:
    v = common.trunc_normal(key, (k, k, c_in, c_out), 0.05)
    return {"v": v, "g": jnp.ones((c_out,)), "b": jnp.zeros((c_out,))}


def _wn_conv(p: Params, x: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    v = p["v"]
    norm = jnp.sqrt(jnp.sum(jnp.square(v), axis=(0, 1, 2), keepdims=True) + 1e-8)
    w = p["g"] * v / norm
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _wn_dense_init(key, d_in: int, d_out: int) -> Params:
    v = common.trunc_normal(key, (d_in, d_out), 0.05)
    return {"v": v, "g": jnp.ones((d_out,)), "b": jnp.zeros((d_out,))}


def _wn_dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    v = p["v"]
    norm = jnp.sqrt(jnp.sum(jnp.square(v), axis=0, keepdims=True) + 1e-8)
    return x @ (p["g"] * v / norm) + p["b"]


def _maxpool(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


class MnistCNN:
    """Input: [B, 28, 28, 1]; 10-way classifier."""

    num_classes = 10

    def __init__(self, widths=(32, 32, 64, 64)):
        self.widths = widths

    def init(self, key) -> Params:
        ks = common.split_keys(key, 5)
        w = self.widths
        return {
            "c1": _wn_conv_init(ks[0], 3, 1, w[0]),
            "c2": _wn_conv_init(ks[1], 3, w[0], w[1]),
            "c3": _wn_conv_init(ks[2], 3, w[1], w[2]),
            "c4": _wn_conv_init(ks[3], 3, w[2], w[3]),
            "fc": _wn_dense_init(ks[4], 7 * 7 * w[3], self.num_classes),
        }

    def forward(self, params, images) -> jnp.ndarray:
        x = images
        x = jax.nn.relu(_wn_conv(params["c1"], x))
        x = jax.nn.relu(_wn_conv(params["c2"], x))
        x = _maxpool(x)                                     # 28 -> 14
        x = jax.nn.relu(_wn_conv(params["c3"], x))
        x = jax.nn.relu(_wn_conv(params["c4"], x))
        x = _maxpool(x)                                     # 14 -> 7
        x = x.reshape(x.shape[0], -1)
        return _wn_dense(params["fc"], x)

    def per_example_loss(self, params, batch) -> jnp.ndarray:
        logits = self.forward(params, batch["images"])
        return common.softmax_cross_entropy(logits, batch["labels"])

    def accuracy(self, params, batch) -> jnp.ndarray:
        logits = self.forward(params, batch["images"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))


def make(widths=(32, 32, 64, 64)) -> MnistCNN:
    return MnistCNN(widths)
