"""Mixture-of-experts FFN: shared + routed experts, top-k, capacity dispatch.

Dispatch is the sort-free scatter/gather formulation:
  1. router softmax -> top-k (expert id, weight) per token
  2. position-in-expert via a one-hot cumulative count (capacity C per expert;
     overflow tokens are dropped, matching GShard/Switch semantics)
  3. scatter tokens to a [E, C, d] buffer, batched expert einsum, weighted
     scatter-add back to [T, d]

Partitioning (cfg.moe.partition_mode):
  * 'tp' — every expert's d_ff is sharded over the 'model' axis (works for
    any expert count, e.g. qwen2-moe's 60); dispatch buffer is replicated
    over 'model' and the down-projection contributes a psum, exactly like a
    dense Megatron MLP.
  * 'ep' — experts are placed over the 'model' axis (requires E_padded %
    model_axis == 0, e.g. deepseek's 64); the dispatch buffer is sharded on
    E, which GSPMD realizes as an all-to-all from the token layout.

The aux load-balance loss follows Switch: E * sum_e f_e * p_e.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params


def padded_num_experts(num_experts: int, multiple: int = 16) -> int:
    return ((num_experts + multiple - 1) // multiple) * multiple


def moe_init(key, cfg, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d = cfg.d_model
    e = padded_num_experts(m.num_experts) if m.partition_mode == "ep" else m.num_experts
    ks = common.split_keys(key, 6)
    p = {
        "router": common.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": _experts_init(ks[1], e, d, m.expert_d_ff, dtype),
        "w_up": _experts_init(ks[2], e, d, m.expert_d_ff, dtype),
        "w_down": _experts_init(ks[3], e, m.expert_d_ff, d, dtype),
    }
    if m.num_shared_experts > 0:
        from repro.models import mlp
        p["shared"] = mlp.mlp_init(ks[4], d, m.shared_d_ff, "swiglu", dtype)
    return p


def _experts_init(key, e: int, d_in: int, d_out: int, dtype):
    std = 1.0 / (d_in ** 0.5)
    return {"w": common.trunc_normal(key, (e, d_in, d_out), std, dtype)}


def route(router_params: Params, x: jnp.ndarray, num_real_experts: int,
          top_k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [T, d] -> (weights [T,k], ids [T,k], probs [T,E], aux_loss)."""
    logits = common.dense(router_params, x.astype(jnp.float32))    # [T, E_padded]
    e_total = logits.shape[-1]
    if num_real_experts < e_total:                                 # mask padding experts
        pad = jnp.arange(e_total) >= num_real_experts
        logits = jnp.where(pad, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, top_k)                     # [T,k]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: fraction routed vs mean prob, per expert
    t = x.shape[0]
    route_onehot = jax.nn.one_hot(top_i[:, 0], e_total, dtype=jnp.float32)
    f = jnp.mean(route_onehot, axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = e_total * jnp.sum(f * pbar)
    return top_w, top_i, probs, aux


def dispatch_indices(top_i: jnp.ndarray, num_experts: int,
                     capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Position of each (token, slot) assignment inside its expert buffer.

    Returns (pos [T,k] int32, keep [T,k] bool). Assignments beyond the
    capacity are dropped (keep=False), GShard-style.
    """
    t, k = top_i.shape
    flat = top_i.reshape(-1)                                       # [T*k]
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)    # [T*k, E]
    pos_flat = (jnp.cumsum(onehot, axis=0) - 1)                    # running count
    pos = jnp.take_along_axis(pos_flat, flat[:, None], axis=1)[:, 0]
    keep = pos < capacity
    return pos.reshape(t, k).astype(jnp.int32), keep.reshape(t, k)


def _dispatch_compute_combine(experts: Params, cfg, x: jnp.ndarray,
                              top_w: jnp.ndarray, top_i: jnp.ndarray,
                              capacity_factor: float) -> jnp.ndarray:
    """Scatter -> batched expert FFN -> weighted gather over LOCAL tokens.

    x: [T_local, d]; top_w/top_i: [T_local, k]. Capacity is computed from
    the local token count (per-group capacity, GShard semantics). Runs
    either plainly (single device / tests / decode) or as the shard_map
    body over the data axes (see moe_apply).
    """
    t, d = x.shape
    e = experts["w_gate"]["w"].shape[0]                            # padded E in 'ep'
    k = top_i.shape[1]
    cap = int(max(1, capacity_factor * t * k / e))
    pos, keep = dispatch_indices(top_i, e, cap)

    # scatter tokens -> [E, C, d]
    buf = jnp.zeros((e, cap + 1, d), x.dtype)                      # +1 drop slot
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    e_flat = top_i.reshape(-1)
    p_flat = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)
    buf = buf.at[e_flat, p_flat].add(x[tok_idx])
    buf = buf[:, :cap]
    if cfg.moe.partition_mode == "ep":
        buf = _maybe_ep_constraint(buf)

    # batched expert FFN (SwiGLU)
    g = jnp.einsum("ecd,edf->ecf", buf, experts["w_gate"]["w"])
    u = jnp.einsum("ecd,edf->ecf", buf, experts["w_up"]["w"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, experts["w_down"]["w"])      # [E,C,d]

    # gather back with routing weights
    y = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))                       # drop slot -> zeros
    gathered = y[e_flat, p_flat]                                   # [T*k, d]
    w_flat = (top_w.reshape(-1) * keep.reshape(-1)).astype(x.dtype)
    return jnp.zeros((t, d), x.dtype).at[tok_idx].add(
        gathered * w_flat[:, None])


def _ambient_axis_sizes():
    from repro.distributed.context import _ambient_axes
    mesh = _ambient_axes()
    if mesh is None:
        return {}
    sizes = (mesh.axis_sizes if hasattr(mesh, "axis_sizes")
             else mesh.devices.shape)
    return dict(zip(mesh.axis_names, sizes))


def _maybe_ep_constraint(buf: jnp.ndarray) -> jnp.ndarray:
    """Expert-parallel: keep the dispatch buffer expert-sharded over the
    'model' axis (GSPMD realizes the reshard as an all-to-all)."""
    from jax.sharding import PartitionSpec as P
    names = _ambient_axis_sizes()
    if "model" not in names or buf.shape[0] % names["model"]:
        return buf
    return jax.lax.with_sharding_constraint(buf, P("model", None, None))


def moe_apply(params: Params, cfg, x: jnp.ndarray,
              capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [T, d] -> (out [T, d], aux_loss scalar).

    Routing (tiny) and the shared experts (a dense MLP) run under plain
    GSPMD. Dispatch/compute/combine runs inside a shard_map over the data
    axes when distributed.context.moe_data_sharding is active — the
    scatter/gather pair is otherwise replicated by GSPMD at GLOBAL size
    (observed 10.7 GB dispatch buffers on qwen2-moe train_4k).
    """
    from jax.sharding import PartitionSpec as P
    from repro.distributed import context
    m = cfg.moe
    t, _ = x.shape
    top_w, top_i, _, aux = route(params["router"], x, m.num_experts, m.top_k)

    experts = {n: params[n] for n in ("w_gate", "w_up", "w_down")}
    axes = context.moe_shard_axes()
    sizes = _ambient_axis_sizes()
    dp_size = 1
    for a in (axes or ()):
        dp_size *= sizes.get(a, 1)
    if axes and t % dp_size == 0 and t >= dp_size:
        dp = axes if len(axes) > 1 else axes[0]
        # XLA CPU WORKAROUND: grad through a partial-auto shard_map with
        # bf16 boundary tensors hits an XLA CPU CHECK failure ("Invalid
        # binary instruction opcode copy", hlo_instruction.cc). Keep the
        # boundary f32 on CPU (dry-run host); interior + TPU stay bf16.
        f32_boundary = (jax.default_backend() == "cpu"
                        and x.dtype == jnp.bfloat16)
        work_dtype = x.dtype

        def body(ex, xx, tw, ti):
            if f32_boundary:
                ex = jax.tree_util.tree_map(
                    lambda a: a.astype(work_dtype), ex)
                xx = xx.astype(work_dtype)
            y = _dispatch_compute_combine(ex, cfg, xx, tw, ti,
                                          capacity_factor)
            return y.astype(jnp.float32) if f32_boundary else y

        args = (experts, x, top_w, top_i)
        if f32_boundary:
            args = (jax.tree_util.tree_map(lambda a: a.astype(jnp.float32),
                                           experts),
                    x.astype(jnp.float32), top_w, top_i)
        out = jax.shard_map(
            body,
            in_specs=(P(), P(dp, None), P(dp, None), P(dp, None)),
            out_specs=P(dp, None),
            axis_names=set(axes), check_vma=False,
        )(*args).astype(x.dtype)
    else:
        out = _dispatch_compute_combine(experts, cfg, x, top_w, top_i,
                                        capacity_factor)

    if "shared" in params:
        from repro.models import mlp
        out = out + mlp.mlp_apply(params["shared"], x, "swiglu")
    return out, aux * m.router_aux_weight


def moe_param_count(cfg, active_only: bool = False) -> int:
    from repro.models import mlp
    m = cfg.moe
    d = cfg.d_model
    e = m.top_k if active_only else m.num_experts
    n = e * 3 * d * m.expert_d_ff                                  # swiglu experts
    n += d * m.num_experts                                         # router
    if m.num_shared_experts > 0:
        n += mlp.mlp_param_count(d, m.shared_d_ff, "swiglu")
    return n
