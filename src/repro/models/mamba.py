"""Mamba-2 / SSD-style selective state-space head (used by Hymba).

Multi-head SSD with scalar-per-head decay a_t = exp(-softplus(dt) * A):

    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T        state: [N, P] per head
    y_t = C_t^T S_t + D x_t

where N = ssm state dim, P = head dim. Sequential scan (oracle/decode) and
chunked-parallel training form (same algebra as rwkv6 but scalar decay per
head, which keeps the chunked form stable without clamping).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params


def ssd_init(key, d_in: int, num_heads: int, head_dim: int, state_dim: int,
             dtype=jnp.float32) -> Params:
    """Projections for a multi-head SSD mixer over input x: [B,S,d_in]."""
    ks = common.split_keys(key, 5)
    h, p, n = num_heads, head_dim, state_dim
    return {
        "wx": common.dense_init(ks[0], d_in, h * p, dtype),       # value path
        "wb": common.dense_init(ks[1], d_in, h * n, dtype),       # input gate B
        "wc": common.dense_init(ks[2], d_in, h * n, dtype),       # output gate C
        "wdt": common.dense_init(ks[3], d_in, h, dtype),          # per-head dt
        "a_log": jnp.zeros((h,), jnp.float32),                    # A = -exp(a_log)
        "d_skip": jnp.ones((h, p), dtype),                        # D skip
        "dt_bias": jnp.zeros((h,), jnp.float32),
    }


def ssd_project(params: Params, x: jnp.ndarray, num_heads: int, head_dim: int,
                state_dim: int):
    b, s, _ = x.shape
    h, p, n = num_heads, head_dim, state_dim
    xv = common.dense(params["wx"], x).reshape(b, s, h, p)
    bb = common.dense(params["wb"], x).reshape(b, s, h, n)
    cc = common.dense(params["wc"], x).reshape(b, s, h, n)
    dt = jax.nn.softplus(common.dense(params["wdt"], x).astype(jnp.float32)
                         + params["dt_bias"])                      # [B,S,H]
    a = -jnp.exp(params["a_log"])                                  # [H], negative
    decay = jnp.exp(dt * a)                                        # in (0,1)
    return xv, bb, cc, dt, decay


def ssd_scan(xv, bb, cc, dt, decay, d_skip, state=None):
    """Sequential oracle. xv: [B,S,H,P]; bb/cc: [B,S,H,N]; dt/decay: [B,S,H]."""
    b, s, h, p = xv.shape
    n = bb.shape[-1]
    f32 = jnp.float32
    xv32, bb32, cc32 = xv.astype(f32), bb.astype(f32), cc.astype(f32)
    if state is None:
        state = jnp.zeros((b, h, n, p), f32)

    def step(st, inp):
        x_t, b_t, c_t, dt_t, a_t = inp
        st = a_t[..., None, None] * st + (dt_t[..., None, None]
                                          * b_t[..., :, None] * x_t[..., None, :])
        y = jnp.einsum("bhn,bhnp->bhp", c_t, st)
        return st, y

    xs = (xv32.transpose(1, 0, 2, 3), bb32.transpose(1, 0, 2, 3),
          cc32.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          decay.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3) + d_skip[None, None] * xv32
    return y.astype(xv.dtype), state


def ssd_chunked(xv, bb, cc, dt, decay, d_skip, state=None, chunk: int = 64):
    """Chunked-parallel SSD (scalar per-head decay => stable log-space form)."""
    b, s, h, p = xv.shape
    n = bb.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((b, h, n, p), f32)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        xv = jnp.pad(xv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    xs_ = xv.astype(f32).reshape(b, nc, chunk, h, p).transpose(1, 0, 3, 2, 4)   # [nc,B,H,C,P]
    bs_ = bb.astype(f32).reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)
    cs_ = cc.astype(f32).reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)
    dts = dt.reshape(b, nc, chunk, h).transpose(1, 0, 3, 2)                      # [nc,B,H,C]
    dcs = decay.reshape(b, nc, chunk, h).transpose(1, 0, 3, 2)

    def chunk_step(st, inp):
        xc, bc, cc_, dtc, ac = inp
        logd = jnp.log(jnp.maximum(ac, 1e-30))                     # [B,H,C]
        acc = jnp.cumsum(logd, axis=-1)                            # inclusive
        # intra-chunk: y_t += sum_{j<=t} C_t·B_j dt_j x_j * exp(acc_t - acc_j)
        scores = jnp.einsum("bhtn,bhjn->bhtj", cc_, bc * dtc[..., None])
        diff = acc[..., :, None] - acc[..., None, :]               # [B,H,C,C]
        tri = jnp.tril(jnp.ones((xc.shape[2], xc.shape[2]), bool))
        gate = jnp.where(tri[None, None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        y = jnp.einsum("bhtj,bhjp->bhtp", scores * gate, xc)
        # carried state: y_t += C_t · exp(acc_t) S_in
        y = y + jnp.einsum("bhtn,bhnp->bhtp", cc_ * jnp.exp(acc)[..., None], st)
        # state update
        a_all = jnp.exp(acc[..., -1])                              # [B,H]
        w_j = jnp.exp(acc[..., -1:] - acc)                         # decay to end
        st = (a_all[..., None, None] * st
              + jnp.einsum("bhjn,bhjp->bhnp", bc * (dtc * w_j)[..., None], xc))
        return st, y

    state, ys = jax.lax.scan(chunk_step, state, (xs_, bs_, cs_, dts, dcs))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, nc * chunk, h, p)[:, :s]
    y = y + d_skip[None, None] * xv[:, :s].astype(f32)
    return y.astype(xv.dtype), state


def ssd_apply(params: Params, x: jnp.ndarray, num_heads: int, head_dim: int,
              state_dim: int, state=None, chunked: bool = True):
    xv, bb, cc, dt, decay = ssd_project(params, x, num_heads, head_dim, state_dim)
    fn = ssd_chunked if (chunked and x.shape[1] > 1) else ssd_scan
    y, state = fn(xv, bb, cc, dt, decay, params["d_skip"].astype(jnp.float32), state)
    return y, state


def ssd_init_state(batch: int, num_heads: int, head_dim: int, state_dim: int):
    return jnp.zeros((batch, num_heads, state_dim, head_dim), jnp.float32)
