"""RWKV-6 language model: embed -> scanned rwkv blocks -> head.

Attention-free; decode state is O(1) per layer (head-state matrices +
token-shift vectors), which makes the ``long_500k`` cell trivial memory-wise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, rwkv6
from repro.models.common import Params


class RWKVLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = common.dtype_of(cfg.dtype)

    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kB, kH = common.split_keys(key, 3)
        keys = jax.random.split(kB, cfg.num_layers)
        return {
            "embed": common.embed_init(kE, cfg.padded_vocab, cfg.d_model, self.dtype),
            "ln_in": common.layernorm_init(cfg.d_model, self.dtype),
            "blocks": jax.vmap(lambda k: rwkv6.rwkv_block_init(k, cfg, self.dtype))(keys),
            "ln_out": common.layernorm_init(cfg.d_model, self.dtype),
            "head": common.dense_init(kH, cfg.d_model, cfg.padded_vocab, self.dtype),
        }

    def _fresh_states(self, batch):
        # zero block state, broadcast over layers inside the scan
        return rwkv6.rwkv_init_block_state(self.cfg, batch, self.dtype)

    def forward(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        b = tokens.shape[0]
        x = common.embed(params["embed"], tokens).astype(self.dtype)
        x = common.layernorm(params["ln_in"], x, 1e-5)
        zero_state = self._fresh_states(b)

        def body(carry, p_l):
            from repro.distributed.context import constrain_layer_params
            h = carry
            p_l = constrain_layer_params(p_l)
            h, _ = rwkv6.rwkv_block_apply(p_l, cfg, h, zero_state, chunked=True)
            return h, None

        from repro.models.transformer import _remat_wrap
        body = _remat_wrap(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        x = common.layernorm(params["ln_out"], x, 1e-5)
        return common.dense(params["head"], x)

    def per_token_loss(self, params, batch):
        labels = batch["labels"]
        logits = self.forward(params, batch["tokens"])
        safe = jnp.maximum(labels, 0)
        loss = common.softmax_cross_entropy(logits, safe, self.cfg.vocab_size)
        return jnp.where(labels >= 0, loss, 0.0), jnp.zeros((), jnp.float32)

    # -- decode: O(1) recurrent state -----------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=None):
        # max_len is irrelevant for a recurrent cache — O(1) in S.
        del max_len
        return {
            "lens": jnp.zeros((), jnp.int32),
            "state": [rwkv6.rwkv_init_block_state(self.cfg, batch, dtype or self.dtype)
                      for _ in range(self.cfg.num_layers)],
        }

    def decode_step(self, params, token, cache):
        cfg = self.cfg
        cache = dict(cache)
        states = list(cache["state"])
        x = common.embed(params["embed"], token).astype(self.dtype)
        x = common.layernorm(params["ln_in"], x, 1e-5)
        for i in range(cfg.num_layers):
            p = jax.tree_util.tree_map(lambda t: t[i], params["blocks"])
            x, states[i] = rwkv6.rwkv_block_apply(p, cfg, x, states[i],
                                                  chunked=False)
        x = common.layernorm(params["ln_out"], x, 1e-5)
        logits = common.dense(params["head"], x)[:, 0]
        cache.update(state=states, lens=cache["lens"] + 1)
        return logits, cache

    def prefill(self, params, tokens, prefix_embeds=None):
        return self.forward(params, tokens)[:, -1]


def make(cfg) -> RWKVLM:
    return RWKVLM(cfg)
