"""Unified decoder-only transformer LM (dense / MoE / VLM families).

Structure: token embed (+ optional multimodal prefix embeds) -> homogeneous
*segments* of pre-norm blocks (each segment is a ``lax.scan`` over stacked
parameters, keeping HLO size O(1) in depth) -> final norm -> (tied) LM head.

Heterogeneity handled:
  * MoE models with leading dense layers (deepseek-v2): one dense segment +
    one MoE segment, scanned separately.
  * Local:global sliding-window interleave (gemma3): a per-layer window
    array is fed through the scan as ``xs`` and applied as a traced mask.
  * Training/prefill scan over layers; decode unrolls layers (small graphs)
    so per-layer caches may differ.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mlp, moe
from repro.models.common import Params

CHUNKED_ATTN_THRESHOLD = 8192   # switch to flash-style chunked path above this


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------


def segments(cfg) -> List[Tuple[str, int, int]]:
    """[(kind, count, first_layer_index)] — homogeneous scan groups."""
    if cfg.moe.enabled:
        fd = cfg.moe.first_dense
        out = []
        if fd > 0:
            out.append(("dense", fd, 0))
        out.append(("moe", cfg.num_layers - fd, fd))
        return out
    return [("dense", cfg.num_layers, 0)]


def layer_windows_np(cfg):
    """Per-layer sliding window (0 = global), host-side (static config math
    — safe under eval_shape/jit tracing)."""
    import numpy as np
    idx = np.arange(cfg.num_layers)
    if cfg.sliding_window <= 0:
        return np.zeros((cfg.num_layers,), np.int32)
    if cfg.global_every > 0:
        is_global = (idx + 1) % cfg.global_every == 0
        return np.where(is_global, 0, cfg.sliding_window).astype(np.int32)
    return np.full((cfg.num_layers,), cfg.sliding_window, np.int32)


def layer_windows(cfg) -> jnp.ndarray:
    return jnp.asarray(layer_windows_np(cfg))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg, kind: str, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    if cfg.attention_kind == "mla":
        attn = attention.mla_init(k1, cfg, dtype)
    else:
        attn = attention.gqa_init(k1, cfg, dtype)
    p = {
        "ln1": common.rmsnorm_init(cfg.d_model, dtype),
        "attn": attn,
        "ln2": common.rmsnorm_init(cfg.d_model, dtype),
    }
    if kind == "moe":
        p["moe"] = moe.moe_init(k2, cfg, dtype)
    else:
        d_ff = cfg.moe.dense_d_ff if (cfg.moe.enabled and cfg.moe.dense_d_ff) else cfg.d_ff
        p["mlp"] = mlp.mlp_init(k2, cfg.d_model, d_ff, cfg.hidden_act, dtype,
                                bias=cfg.use_bias)
    return p


def block_apply(p: Params, cfg, kind: str, x: jnp.ndarray, positions: jnp.ndarray,
                window) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # tp.col_in / tp.row_out are identity unless the SPMD engine's manual
    # tensor-parallel context is ambient (docs/spmd.md): then the qkv
    # projections consume head-sharded weights (psum on the backward pass)
    # and wo / w_down produce partial sums merged by a forward psum.
    from repro.distributed import tp
    h = common.rmsnorm(p["ln1"], x, cfg.norm_eps)
    h = tp.col_in(h, "attn")
    if cfg.attention_kind == "mla":
        attn_out = attention.mla_attend(p["attn"], cfg, h, positions)
    elif x.shape[1] > CHUNKED_ATTN_THRESHOLD:
        attn_out = attention.gqa_attend_chunked(p["attn"], cfg, h, positions,
                                                window=window)
    else:
        attn_out = attention.gqa_attend(p["attn"], cfg, h, positions, window=window)
    x = x + tp.row_out(attn_out, "attn")
    h = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        b, s, d = h.shape
        out, aux = moe.moe_apply(p["moe"], cfg, h.reshape(b * s, d),
                                 cfg.moe.capacity_factor)
        out = out.reshape(b, s, d)
    else:
        h = tp.col_in(h, "ffn")
        out = tp.row_out(mlp.mlp_apply(p["mlp"], h, cfg.hidden_act), "ffn")
        aux = jnp.zeros((), jnp.float32)
    return x + out, aux


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class TransformerLM:
    """Families: dense | moe | vlm. Pure-function methods over a param pytree."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = common.dtype_of(cfg.dtype)

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kH, *seg_keys = jax.random.split(key, 2 + len(segments(cfg)))
        params: Params = {
            "embed": common.embed_init(kE, cfg.padded_vocab, cfg.d_model, self.dtype),
            "final_norm": common.rmsnorm_init(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = common.dense_init(kH, cfg.d_model, cfg.padded_vocab,
                                                  self.dtype)
        for (kind, count, _), sk in zip(segments(cfg), seg_keys):
            keys = jax.random.split(sk, count)
            params[f"seg_{kind}"] = jax.vmap(
                lambda k: block_init(k, cfg, kind, self.dtype))(keys)
        return params

    # -- forward (train / prefill) -------------------------------------------

    def _embed_inputs(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = common.embed(params["embed"], tokens).astype(self.dtype)
        if cfg.embed_scale != 1.0:
            x = x * jnp.asarray(cfg.embed_scale, self.dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(self.dtype), x], axis=1)
        return x

    def _run_segments(self, params, x, positions):
        cfg = self.cfg
        windows = layer_windows(cfg)
        aux_total = jnp.zeros((), jnp.float32)
        for kind, count, first in segments(cfg):
            stacked = params[f"seg_{kind}"]
            seg_windows = jax.lax.dynamic_slice_in_dim(windows, first, count)

            def body(carry, xs, _kind=kind):
                from repro.distributed.context import (constrain_activations,
                                                       constrain_layer_params)
                h, aux = carry
                p_l, win = xs
                p_l = constrain_layer_params(p_l)
                h, a = block_apply(p_l, cfg, _kind, h, positions, win)
                # sequence-parallel residual stream (no-op unless enabled):
                # the scan carry is the saved activation under remat, so
                # this constraint divides activation memory by |model|
                h = constrain_activations(h)
                return (h, aux + a), None

            body = _remat_wrap(body, cfg.remat)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             (stacked, seg_windows))
        return x, aux_total

    def forward(self, params, tokens, prefix_embeds=None) -> jnp.ndarray:
        """tokens: [B, S_text] -> logits [B, S_total, V_padded]
        (the LOCAL vocab slice under the engine's manual TP context)."""
        from repro.distributed import tp
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, prefix_embeds)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux = self._run_segments(params, x, positions)
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        out_w = self._output_weights(params)
        return tp.col_in(x, "vocab") @ out_w

    def _output_weights(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"]["embedding"].T
        return params["lm_head"]["w"]

    # -- loss ----------------------------------------------------------------

    def per_token_loss(self, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Returns (per_token_loss [B, S_total], aux_loss scalar).

        batch: tokens [B,S], labels [B,S] (-1 = masked), optional
        prefix_embeds [B,P,d]. Prefix positions carry zero loss.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        prefix = batch.get("prefix_embeds")
        x = self._embed_inputs(params, tokens, prefix)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux = self._run_segments(params, x, positions)
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if prefix is not None:
            p = prefix.shape[1]
            pad_labels = jnp.full((labels.shape[0], p), -1, labels.dtype)
            labels = jnp.concatenate([pad_labels, labels], axis=1)
        from repro.distributed import tp
        b, s, d = x.shape
        out_w = self._output_weights(params)
        safe_labels = jnp.maximum(labels, 0)
        x = tp.col_in(x, "vocab")               # manual-TP head: local logits
        if cfg.padded_vocab * s > 32_000_000:   # big logits: chunk over tokens
            loss = common.chunked_cross_entropy(
                x.reshape(b * s, d), out_w, safe_labels.reshape(b * s),
                cfg.vocab_size).reshape(b, s)
        else:
            logits = x @ out_w
            loss = common.softmax_cross_entropy(logits, safe_labels, cfg.vocab_size)
        loss = jnp.where(labels >= 0, loss, 0.0)
        return loss, aux

    # -- decode (unrolled layers, per-layer caches) ---------------------------

    def init_cache(self, batch: int, max_len: int, dtype=None) -> Dict[str, Any]:
        """dtype=jnp.int8 selects quantized GQA caches (per-token scales);
        MLA caches stay bf16 — the latent is already 4-8x compressed."""
        cfg = self.cfg
        dtype = dtype or self.dtype
        mla_dtype = jnp.bfloat16 if dtype == jnp.int8 else dtype
        cache: Dict[str, Any] = {"lens": jnp.zeros((), jnp.int32)}
        windows = [int(w) for w in layer_windows_np(cfg)]
        for kind, count, first in segments(cfg):
            layer_caches = []
            for i in range(count):
                w = windows[first + i]
                s = min(max_len, w) if w > 0 else max_len
                if cfg.attention_kind == "mla":
                    layer_caches.append(attention.mla_init_cache(cfg, batch, s,
                                                                 mla_dtype))
                else:
                    layer_caches.append(attention.gqa_init_cache(cfg, batch, s, dtype))
            cache[f"seg_{kind}"] = layer_caches
        return cache

    def decode_step(self, params, token: jnp.ndarray, cache: Dict[str, Any]
                    ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """token: [B, 1] -> (logits [B, V_padded], new cache).

        Layers are unrolled; each layer's cache may have its own length
        (window-limited for local layers). Window-limited caches use
        position ``cache_len % window`` as a ring buffer.
        """
        cfg = self.cfg
        cache = dict(cache)
        cache_len = cache["lens"]
        x = self._embed_inputs(params, token)
        windows = [int(w) for w in layer_windows_np(cfg)]
        for kind, count, first in segments(cfg):
            stacked = params[f"seg_{kind}"]
            seg_cache = list(cache[f"seg_{kind}"])
            for i in range(count):
                p_l = jax.tree_util.tree_map(lambda t: t[i], stacked)
                w = windows[first + i]
                x, seg_cache[i] = self._decode_block(p_l, cfg, kind, x,
                                                     seg_cache[i], cache_len, w)
            cache[f"seg_{kind}"] = seg_cache
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ self._output_weights(params))[:, 0]
        cache["lens"] = cache_len + 1
        return logits, cache

    def _decode_block(self, p, cfg, kind, x, layer_cache, cache_len, window):
        h = common.rmsnorm(p["ln1"], x, cfg.norm_eps)
        cache_size = (layer_cache["c_kv"] if cfg.attention_kind == "mla"
                      else layer_cache["k"]).shape[1]
        is_ring = window > 0 and cache_size <= window
        if cfg.attention_kind == "mla":
            attn_out, layer_cache = attention.mla_decode(
                p["attn"], cfg, h, layer_cache, cache_len)
        else:
            # Ring-buffer local caches hold exactly the last `window` tokens:
            # write at cache_len % size; every slot is valid once wrapped
            # (validity in gqa_decode is kpos <= cache_len, trivially true),
            # and RoPE still uses the true position cache_len.
            attn_out, layer_cache = attention.gqa_decode(
                p["attn"], cfg, h, layer_cache, cache_len,
                window=0 if is_ring else window,
                write_pos=cache_len % cache_size if is_ring else None)
        x = x + attn_out
        h = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            b = h.shape[0]
            out, _ = moe.moe_apply(p["moe"], cfg, h.reshape(b, -1),
                                   cfg.moe.capacity_factor)
            out = out.reshape(b, 1, -1)
        else:
            out = mlp.mlp_apply(p["mlp"], h, cfg.hidden_act)
        return x + out, layer_cache

    def prefill(self, params, tokens, prefix_embeds=None):
        """Prefill: run the stack, return ONLY the last position's logits
        [B, V] (what a server samples from). The compute-dominant stack is
        identical to forward(); projecting a single position avoids a
        [B, S, V] logits buffer. ``tests/test_serve.py`` validates decode
        correctness by stepping decode_step against forward()."""
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, prefix_embeds)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _ = self._run_segments(params, x, positions)
        x = common.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
        return (x @ self._output_weights(params))[:, 0]


def make(cfg) -> TransformerLM:
    return TransformerLM(cfg)
