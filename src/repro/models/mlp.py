"""Dense FFN blocks: SwiGLU / GELU / squared-ReLU."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32,
             bias: bool = False) -> Params:
    ks = common.split_keys(key, 3)
    p = {
        "w_up": common.dense_init(ks[0], d_model, d_ff, dtype, bias=bias),
        "w_down": common.dense_init(ks[1], d_ff, d_model, dtype, bias=bias),
    }
    if act == "swiglu":
        p["w_gate"] = common.dense_init(ks[2], d_model, d_ff, dtype, bias=bias)
    return p


def mlp_apply(params: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    f = common.activation(act)
    up = common.dense(params["w_up"], x)
    if act == "swiglu":
        h = f(common.dense(params["w_gate"], x)) * up
    else:
        h = f(up)
    return common.dense(params["w_down"], h)


def mlp_param_count(d_model: int, d_ff: int, act: str) -> int:
    n = 2 * d_model * d_ff
    if act == "swiglu":
        n += d_model * d_ff
    return n
