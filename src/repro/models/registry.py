"""Model registry: family -> module, plus allocation-free parameter counts."""
from __future__ import annotations

import functools
import math
from typing import Any

import jax


def get_model(cfg) -> Any:
    family = cfg.family
    if family in ("dense", "moe", "vlm"):
        from repro.models import transformer
        return transformer.make(cfg)
    if family == "hybrid":
        from repro.models import hymba
        return hymba.make(cfg)
    if family == "ssm":
        from repro.models import rwkv_lm
        return rwkv_lm.make(cfg)
    if family == "audio":
        from repro.models import whisper
        return whisper.make(cfg)
    raise ValueError(f"unknown model family: {family}")


@functools.lru_cache(maxsize=64)
def _shape_tree(cfg):
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(model.init, key)


def param_count(cfg, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape (no allocation, works at 104B)."""
    shapes = _shape_tree(cfg)
    total = sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(shapes))
    if active_only and cfg.moe.enabled:
        # subtract the routed experts a token does NOT visit
        m = cfg.moe
        moe_shapes = shapes.get("seg_moe", {}).get("moe", {})
        for name in ("w_gate", "w_up", "w_down"):
            if name in moe_shapes:
                w = moe_shapes[name]["w"]          # [L_moe, E, d_in, d_out]
                per_expert = math.prod(w.shape) // w.shape[1]
                total -= per_expert * (w.shape[1] - m.top_k)
    return total


def embedding_param_count(cfg) -> int:
    shapes = _shape_tree(cfg)
    n = 0
    for key_name in ("embed", "lm_head", "head"):
        sub = shapes.get(key_name)
        if sub:
            n += sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(sub))
    return n
