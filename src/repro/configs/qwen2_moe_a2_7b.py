"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts (shared d_ff = 4*1408 = 5632).
60 % 16 != 0 => expert-TP partitioning (shard every expert's d_ff).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,
    vocab_size=151936,
    hidden_act="swiglu",
    use_bias=False,
    moe=MoEConfig(
        num_experts=60,
        num_shared_experts=4,
        top_k=4,
        expert_d_ff=1408,
        shared_d_ff=5632,
        partition_mode="tp",
    ),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=176,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        # capacity_factor=8 => cap = T*k: drop-free, so decode-vs-forward
        # equivalence is exact (capacity drops differ across batch shapes)
        moe=MoEConfig(num_experts=8, num_shared_experts=2, top_k=2,
                      expert_d_ff=44, shared_d_ff=88, partition_mode="tp",
                      capacity_factor=8.0),
    )
