"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

27L d_model=2048 16H, MLA kv_lora=512 (rope 64 / nope 128 / v 128),
vocab=102400; MoE: 64 routed experts top-6 + 2 shared (d_ff 1408 each),
first layer dense (d_ff=10944). 64 % 16 == 0 => expert-parallel over the
model axis. (Assignment header says "MoE 64e top-6"; the "160 routed" in
its tail note is the non-Lite V2 — we follow the 64e Lite spec.)
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,
    vocab_size=102400,
    attention_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_rope_dim=64,
                  qk_nope_dim=128, v_head_dim=128),
    hidden_act="swiglu",
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        expert_d_ff=1408,
        shared_d_ff=2816,
        first_dense=1,
        dense_d_ff=10944,
        partition_mode="ep",
    ),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        attention_kind="mla",
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_rope_dim=8,
                      qk_nope_dim=16, v_head_dim=16),
        # capacity_factor=8 => drop-free (see qwen2_moe smoke note)
        moe=MoEConfig(num_experts=8, num_shared_experts=2, top_k=2,
                      expert_d_ff=32, shared_d_ff=64, first_dense=1,
                      dense_d_ff=128, partition_mode="ep",
                      capacity_factor=8.0),
    )
