"""Minitron-4B (pruned Nemotron) [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, squared-ReLU FFN.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    hidden_act="relu_sq",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        d_ff=288,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        hidden_act="relu_sq",
    )
