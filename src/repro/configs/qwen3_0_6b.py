"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B family spec].

28L d_model=1024 16H (GQA kv=8, head_dim=128) d_ff=3072 vocab=151936,
qk-norm, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    hidden_act="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        qk_norm=True,
        tie_embeddings=True,
    )
