"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus spec].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, no biases, tied
embeddings. The scale case: TP=16 + ZeRO-1 sharded optimizer state are
required to fit; gradient all-reduce traffic dominates — this is the
paper-representative hillclimb target.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    hidden_act="swiglu",
    use_bias=False,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=352,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        tie_embeddings=True,
    )
