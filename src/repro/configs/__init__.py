"""Architecture registry: ``--arch <id>`` -> (full config, smoke config)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, MeshConfig, MLAConfig,
                                ModelConfig, MoEConfig, MULTI_POD_MESH,
                                OptimizerConfig, ShapeConfig, SHAPES,
                                SHAPES_BY_NAME, SINGLE_POD_MESH, SSMConfig,
                                TrainConfig, replace)

_ARCH_MODULES: Dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "internvl2-2b": "internvl2_2b",
    "gemma3-1b": "gemma3_1b",
    "qwen3-0.6b": "qwen3_0_6b",
    "minitron-4b": "minitron_4b",
    "command-r-plus-104b": "command_r_plus_104b",
    "hymba-1.5b": "hymba_1_5b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-tiny": "whisper_tiny",
}

# archs whose long_500k cell is skipped (pure full-attention; see DESIGN.md)
LONG_CONTEXT_ARCHS = ("gemma3-1b", "hymba-1.5b", "rwkv6-1.6b")


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def cell_is_skipped(arch: str, shape_name: str) -> bool:
    return shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
