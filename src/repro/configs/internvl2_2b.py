"""InternVL2-2B [arXiv:2404.16821]: InternViT frontend (STUB) + InternLM2 LM.

Backbone: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The vision
frontend is a stub per the assignment — input_specs() supplies 256
precomputed patch embeddings [B, 256, d_model] (448px / patch14 with pixel
unshuffle), spliced ahead of the text tokens; labels are masked there.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    hidden_act="swiglu",
    num_prefix_embeds=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        num_prefix_embeds=8,
    )
