"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892].

24L d_model=2048 (attention-free, head_dim=64 => 32 wkv heads) d_ff=7168
vocab=65536, data-dependent decay. long_500k runs: O(1) recurrent state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    attention_kind="none",
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        attention_kind="none",
        d_ff=224,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        rwkv_head_dim=16,
    )
