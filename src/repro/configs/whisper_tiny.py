"""Whisper-tiny [arXiv:2212.04356]: 4L enc + 4L dec, d_model=384 6H
(kv=6) d_ff=1536 vocab=51865. Conv frontend STUBBED: input_specs()
supplies 1500 precomputed frame embeddings. long_500k skipped (full
attention enc-dec); decode shapes exercise the decoder with self + cross
caches.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    hidden_act="gelu",
    use_bias=True,
    is_encoder_decoder=True,
    num_encoder_layers=4,
    encoder_seq_len=1500,
    max_seq_len=65536,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        use_bias=True,
        is_encoder_decoder=True,
        num_encoder_layers=2,
        encoder_seq_len=16,
        max_seq_len=512,
        tie_embeddings=True,
    )
