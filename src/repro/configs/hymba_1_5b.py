"""Hymba-1.5B [arXiv:2411.13676]: parallel attention + SSM heads per block.

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
ssm_state=16. Attention is sliding-window (1024) in every block (Hymba
keeps 3 global layers; we use window-everywhere so the SSM path carries
long-range state — recorded in DESIGN.md §Arch-applicability). long_500k
runs: O(window) attention cache + O(1) SSM state.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hidden_act="swiglu",
    sliding_window=1024,
    hybrid_parallel=True,
    ssm=SSMConfig(state_dim=16),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke",
        family="hybrid",
        num_layers=2,
        d_model=80,
        num_heads=5,
        num_kv_heads=1,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        sliding_window=8,
        hybrid_parallel=True,
        ssm=SSMConfig(state_dim=4),
    )
