"""Config dataclasses for models, shapes, meshes, and training.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG`` (the exact published configuration) and ``smoke_config()`` (a reduced
same-family config for CPU tests). The registry in ``repro.configs.__init__``
maps ``--arch <id>`` strings to these modules.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (shared + routed experts)."""

    num_experts: int = 0              # routed experts
    num_shared_experts: int = 0       # always-on experts
    top_k: int = 2
    expert_d_ff: int = 0              # d_ff of each routed expert
    shared_d_ff: int = 0              # total d_ff of the shared expert block
    router_aux_weight: float = 0.001  # load-balance aux loss weight
    first_dense: int = 0              # leading dense (non-MoE) layers
    dense_d_ff: int = 0               # d_ff of those leading dense layers
    capacity_factor: float = 1.25
    # 'tp' shards every expert's d_ff over the model axis (works for any E);
    # 'ep' places E/model_size experts per shard with all-to-all dispatch
    # (requires padded E % model_axis == 0).
    partition_mode: str = "tp"

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 => full-rank q projection
    qk_rope_dim: int = 64             # per-head rope sub-dimension
    qk_nope_dim: int = 128            # per-head non-rope sub-dimension
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba/SSD-style state-space head config (used by hymba, rwkv6)."""

    state_dim: int = 16
    conv_dim: int = 4                 # depthwise conv width (mamba)
    expand: int = 2                   # inner dim multiplier
    num_heads: int = 0                # SSD heads (0 => derive)

    @property
    def enabled(self) -> bool:
        return self.state_dim > 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single unified model description covering all 10 assigned archs."""

    name: str = "model"
    family: str = "dense"             # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0                 # 0 => d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 1024
    max_seq_len: int = 8192

    # attention details
    attention_kind: str = "gqa"       # gqa | mla | none (attn-free)
    mla: MLAConfig = MLAConfig(kv_lora_rank=0)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # sliding-window pattern: window size and the local:global interleave.
    # sliding_window=0 => all layers global. global_every=k => layer i is
    # global iff (i+1) % k == 0 (gemma3's 5 local : 1 global).
    sliding_window: int = 0
    global_every: int = 0
    attn_logit_softcap: float = 0.0

    # ffn
    hidden_act: str = "swiglu"        # swiglu | gelu | relu_sq
    moe: MoEConfig = MoEConfig()

    # alternative token mixers
    ssm: SSMConfig = SSMConfig()      # hybrid/ssm families
    # hymba: parallel attn + ssm heads in the same block
    hybrid_parallel: bool = False

    # rwkv6 specifics
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper's fixed 30s -> 1500 frames

    # multimodal stubs: number of prefix embedding positions supplied
    # pre-computed by the (stubbed) frontend; 0 disables.
    num_prefix_embeds: int = 0

    use_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: float = 1.0          # gemma multiplies embeds by sqrt(d)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"           # activation/param dtype for dry-runs
    vocab_pad_multiple: int = 128

    # remat policy for the scanned blocks: 'none'|'full'|'dots'
    remat: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops accounting)."""
        from repro.models import registry  # local import to avoid cycles

        return registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry

        return registry.param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (the four assigned shape cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


# ---------------------------------------------------------------------------
# Mesh / distribution configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Logical mesh description. axes are named; 'pod' optional."""

    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis_size(self) -> int:
        return dict(zip(self.axes, self.shape)).get("model", 1)

    @property
    def data_parallel_size(self) -> int:
        d = dict(zip(self.axes, self.shape))
        return d.get("pod", 1) * d.get("data", 1)


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Aggregation / training configuration (the paper's knobs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AggregationConfig:
    """The paper's Sync/Async/backup-worker policy knobs.

    Strategies are constructed by ``repro.core.registry.get_strategy``:
      'full_sync'  — paper's plain Sync-Opt (wait for all N+b == all workers)
      'backup'     — paper's Alg. 3/4: aggregate first N of N+b, drop b
      'timeout'    — paper §6 future-work variant: aggregate all arrivals
                     within deadline_s of the first (>=1 always aggregated)
      'async'      — paper's Alg. 1/2 baseline (event-driven)
      'softsync'   — Zhang et al. (2015b) related-work baseline: async apply
                     every c arrivals (stale allowed) — for comparisons only
      'staleness'  — paper §2.1 controlled rig: serial SGD applying the
                     gradient from staleness_tau steps ago
      'dynamic_backup' — Dynamic Backup Workers (arXiv:2102.06280):
                     backup strategy whose cutoff N adapts online from
                     the measured straggler tail (docs/robustness.md)
    """

    strategy: str = "backup"
    num_workers: int = 16             # N
    backup_workers: int = 0           # b  (total launched = N + b)
    deadline_s: float = 0.0           # timeout strategy
    softsync_c: int = 1
    # dynamic_backup strategy (arXiv:2102.06280): adapt the aggregate-
    # first-N cutoff online from the measured straggler tail. window =
    # steps of arrival history kept; min_workers = smallest N the
    # controller may choose (0 => max(1, num_workers // 2)).
    dynamic_window: int = 32
    dynamic_min_workers: int = 0
    # where dynamic_backup's adaptation window comes from: 'sim' (the
    # straggler simulator's arrival model) or 'measured' (fenced
    # wall-clock per-worker step times fed by the trainer — see
    # docs/observability.md; host straggler backend only)
    latency_source: str = "sim"
    staleness_tau: int = 0            # staleness strategy: target tau
    staleness_ramp_steps: int = 0     # ramp tau up over the first steps
    staleness_jitter: int = 0         # +- uniform jitter on tau
    # gradient compression over the wire: 'none' | 'bf16' | 'int8_ef'
    compression: str = "none"
    # reduce-scatter + ZeRO-1 instead of all-reduce + replicated opt state
    zero1: bool = False

    @property
    def total_workers(self) -> int:
        return self.num_workers + self.backup_workers


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "rmsprop_momentum"    # paper's optimizer for Inception
    learning_rate: float = 0.045
    # paper's rule-of-thumb: lr scales linearly with N (A.3: 0.045*N)
    scale_lr_with_workers: bool = True
    decay: float = 0.9                # rmsprop decay
    momentum: float = 0.9
    eps: float = 1e-8
    beta1: float = 0.9                # adam
    beta2: float = 0.999
    weight_decay: float = 0.0
    # exponential schedule gamma0 * beta^(t*N/(2T)) (paper A.2/A.3)
    lr_decay_rate: float = 0.94
    steps_per_epoch: int = 0          # T = |X|/B; 0 disables the schedule
    # linear anneal to 0 over [linear_anneal_from, linear_anneal_steps]
    # (paper A.1 MNIST recipe); >0 takes precedence over the exponential
    linear_anneal_steps: int = 0
    linear_anneal_from: int = 0
    warmup_steps: int = 0
    clip_global_norm: float = 0.0     # >0 enables (async needs it; sync not)
    ema_decay: float = 0.9999         # paper evaluates on EMA of params


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How the W coordination workers are executed.

    'sim'  — single-device simulation: workers are contiguous row blocks
             of one global batch on one device (every PR-1/PR-3 path).
    'spmd' — the SPMD execution engine (repro.distributed.spmd_engine):
             workers are laid out over a real mesh 'data' axis via
             shard_map, per-worker gradients live on their shard, and
             masked aggregation is a collective (in-shard backup_reduce
             + psum) — no stacked [W, ...] gradient tree ever exists on
             one device. With mesh_model > 1 params, optimizer state and
             EMA are additionally SHARDED over the mesh 'model' axis and
             each worker's gradient is computed tensor-parallel inside
             its 'data' shard (explicit psums over 'model' at the
             contracted dims — sharding.tp_plan decides which groups
             shard; indivisible configs fall back to a carried,
             replicated axis with a warning). Strategies advertise
             support via ``registry.supports_spmd`` (TP opt-out:
             ``spmd_tp_supported = False``); unsupported strategies fall
             back to 'sim' with a warning.
    """

    backend: str = "sim"              # 'sim' | 'spmd'
    mesh_data: int = 1                # 'data' axis size (devices); W % it == 0
    mesh_model: int = 1               # 'model' (tensor-parallel) axis size
    # in-shard reduce: the kernels/backup_reduce Pallas kernel (True) or
    # the jnp reference reduction (False). None = auto: the kernel on
    # TPU (where it runs natively), the jnp dot elsewhere — interpret-
    # mode Pallas is pure overhead off-TPU (docs/spmd.md, BENCH_spmd)
    use_kernel: Optional[bool] = None
    # Pallas interpret mode: None = auto (interpret off TPU), or forced
    interpret: Optional[bool] = None
    # per-worker gradient batching inside each 'data' shard: 0 = vmap ALL
    # local workers (one fused program, the fast path when activation
    # memory allows), 1 = sequential lax.map (one worker's activations at
    # a time), k = microbatches of k vmapped workers (k must divide
    # total_workers / mesh_data — validated with a structured error)
    grad_batch: int = 0
    # fused bucketed reduce-then-psum (kernels/bucketed_reduce): lanes of
    # the flattened gradient per collective. 0 = one bucket (a single
    # psum carries gradient + monitoring scalars); >0 cuts the flatten
    # into fixed-size buckets whose psums overlap the remaining reduce
    # compute under the latency-hiding XLA recipe (docs/spmd.md)
    bucket_size: int = 0

    @property
    def num_devices(self) -> int:
        return self.mesh_data * self.mesh_model


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "checkpoints"
    every_steps: int = 100
    keep: int = 3
    async_save: bool = False
    # self-healing writes (docs/robustness.md): failed saves retry up to
    # write_retries times with seeded-jittered exponential backoff —
    # capped at retry_max_backoff_s, scaled by uniform [1, 1+retry_jitter]
    # — before the error propagates (where the supervisor takes over)
    write_retries: int = 3
    retry_backoff_s: float = 0.01
    retry_max_backoff_s: float = 0.25
    retry_jitter: float = 0.5


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault injection + recovery supervision (docs/robustness.md).

    ``spec`` is a chaos-plan string parsed by ``repro.core.faults``
    (e.g. ``"crash@10:w1,slowdown@20:w2,ckpt_io@25,preempt@35"`` or
    ``"crash=2,slowdown=3"`` for seeded-random placement). ``seed`` is
    the fault stream's own seed — independent of ``TrainConfig.seed`` so
    the same training run can be replayed under different chaos.
    ``supervise`` routes the run through
    ``repro.train.supervisor.run_supervised`` (crash recovery from the
    last verified-good checkpoint, bounded by ``max_restarts``).
    """

    spec: str = ""
    seed: int = 0
    supervise: bool = False
    max_restarts: int = 3


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = ModelConfig()
    shape: ShapeConfig = SHAPES_BY_NAME["train_4k"]
    mesh: MeshConfig = SINGLE_POD_MESH
    aggregation: AggregationConfig = AggregationConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    checkpoint: CheckpointConfig = CheckpointConfig()
    execution: ExecutionConfig = ExecutionConfig()
    faults: FaultConfig = FaultConfig()
    seed: int = 0
    total_steps: int = 1000
    log_every: int = 10
    microbatch: int = 0               # 0 => derive from shape & mesh
    # fused chunked loop: iterations per device dispatch. 1 = legacy
    # per-step (mask) / per-arrival (event) path; >1 fuses K iterations —
    # SPMD steps for mask strategies, PS updates for event strategies —
    # into one lax.scan with chunk boundaries forced at checkpoint /
    # kill-injection / rescale steps.
    chunk_size: int = 1
    # mask strategies only (event arrivals are always host-scheduled):
    # 'host'   — numpy straggler streams, bit-exact with the legacy path
    # 'device' — jax.random sampling + select_jax inside the scan body
    #            (distribution-equivalent, zero host work per step)
    straggler_backend: str = "host"
    # ChunkPrefetcher look-ahead: how many upcoming chunks are built on
    # the background thread while the device runs the current dispatch
    # (1 = classic double buffering; generation is pure in (cfg, step),
    # so deeper speculation never changes the batches)
    prefetch_depth: int = 1


def replace(cfg, **kw):
    """dataclasses.replace passthrough (ergonomic alias)."""
    return dataclasses.replace(cfg, **kw)
