"""Gemma-3 1B [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1, head_dim=256) d_ff=6912 vocab=262144;
5 local (sliding window 512) : 1 global interleave; qk-norm; tied
embeddings scaled by sqrt(d). long_500k runs for this arch (local layers
are sub-quadratic; the interleaved global layers are O(S) at decode).
"""
import math

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    hidden_act="gelu",
    qk_norm=True,
    sliding_window=512,
    global_every=6,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=math.sqrt(1152.0),
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        vocab_pad_multiple=16,
        dtype="float32",
        remat="none",
        qk_norm=True,
        sliding_window=8,
        global_every=3,
        tie_embeddings=True,
        embed_scale=8.0,
    )
