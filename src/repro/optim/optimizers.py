"""Stochastic optimizers (pure JAX, optax-free by design).

The paper's Inception runs use RMSProp-with-momentum (decay 0.9, momentum
0.9); PixelCNN uses RMSProp (decay 0.95). SGD/momentum/Adam/AdaGrad round
out the family the paper cites (Duchi 2011, Kingma & Ba 2014, Tieleman &
Hinton 2012).

Interface:
    opt = make_optimizer(cfg, schedule)
    state = opt.init(params)
    new_params, new_state, stats = opt.apply(params, grads, state, step)

All state is a pytree mirroring params — checkpointable and shardable with
the same rules as the gradients (ZeRO-1 shards it over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
State = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], State]
    apply: Callable[[Params, Params, State, jnp.ndarray], Tuple[Params, State, Dict]]


def _treemap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    """Paper §A.3: Async-Opt requires global-norm clipping; Sync does not."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _treemap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _f32_like(params):
    return _treemap(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(schedule) -> Optimizer:
    def init(params):
        return {}

    def apply(params, grads, state, step):
        lr = schedule(step)
        new = _treemap(lambda p, g: (p.astype(jnp.float32)
                                     - lr * g.astype(jnp.float32)).astype(p.dtype),
                       params, grads)
        return new, state, {"lr": lr}

    return Optimizer(init, apply)


def momentum(schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": _f32_like(params)}

    def apply(params, grads, state, step):
        lr = schedule(step)
        m = _treemap(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                     state["m"], grads)
        upd = (_treemap(lambda m_, g: beta * m_ + g.astype(jnp.float32), m, grads)
               if nesterov else m)
        new = _treemap(lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
                       params, upd)
        return new, {"m": m}, {"lr": lr}

    return Optimizer(init, apply)


def rmsprop_momentum(schedule, decay: float = 0.9, mom: float = 0.9,
                     eps: float = 1e-8) -> Optimizer:
    """The paper's optimizer (RMSProp w/ momentum, TF-style)."""

    def init(params):
        return {"ms": _f32_like(params), "mom": _f32_like(params)}

    def apply(params, grads, state, step):
        lr = schedule(step)
        ms = _treemap(lambda s, g: decay * s + (1 - decay) * jnp.square(g.astype(jnp.float32)),
                      state["ms"], grads)
        mo = _treemap(lambda m_, s, g: mom * m_ + lr * g.astype(jnp.float32)
                      / jnp.sqrt(s + eps),
                      state["mom"], ms, grads)
        new = _treemap(lambda p, m_: (p.astype(jnp.float32) - m_).astype(p.dtype),
                       params, mo)
        return new, {"ms": ms, "mom": mo}, {"lr": lr}

    return Optimizer(init, apply)


def adam(schedule, beta1: float = 0.9, beta2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _f32_like(params), "v": _f32_like(params)}

    def apply(params, grads, state, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        m = _treemap(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32),
                     state["m"], grads)
        v = _treemap(lambda v_, g: beta2 * v_ + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
                     state["v"], grads)
        bc1 = 1 - beta1 ** t
        bc2 = 1 - beta2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new = _treemap(upd, params, m, v)
        return new, {"m": m, "v": v}, {"lr": lr}

    return Optimizer(init, apply)


def adagrad(schedule, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"acc": _f32_like(params)}

    def apply(params, grads, state, step):
        lr = schedule(step)
        acc = _treemap(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                       state["acc"], grads)
        new = _treemap(lambda p, a, g: (p.astype(jnp.float32)
                                        - lr * g.astype(jnp.float32)
                                        / (jnp.sqrt(a) + eps)).astype(p.dtype),
                       params, acc, grads)
        return new, {"acc": acc}, {"lr": lr}

    return Optimizer(init, apply)


def make_optimizer(opt_cfg, schedule) -> Optimizer:
    name = opt_cfg.name
    if name == "sgd":
        return sgd(schedule)
    if name == "momentum":
        return momentum(schedule, opt_cfg.momentum)
    if name == "rmsprop_momentum":
        return rmsprop_momentum(schedule, opt_cfg.decay, opt_cfg.momentum, opt_cfg.eps)
    if name == "rmsprop":
        return rmsprop_momentum(schedule, opt_cfg.decay, 0.0, opt_cfg.eps)
    if name == "adam":
        return adam(schedule, opt_cfg.beta1, opt_cfg.beta2, opt_cfg.eps,
                    opt_cfg.weight_decay)
    if name == "adagrad":
        return adagrad(schedule)
    raise ValueError(f"unknown optimizer {name!r}")
