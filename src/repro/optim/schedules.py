"""Learning-rate schedules from the paper's appendices.

A.2/A.3 (Inception): lr(t) = γ0 · β^(t·N/(2T)), β=0.94, γ0 = 0.045·N for
Sync-Opt — the decay exponent is scaled by N so that the lr after a fixed
number of *datapoints* matches between Sync and Async.
A.1 (MNIST): constant then linear anneal to 0 over the last epochs.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(gamma0: float, beta: float, steps_per_epoch: int,
                      num_workers: int = 1) -> Schedule:
    """Paper: gamma0 * beta^(t*N/(2T)); T = |X|/B steps per epoch."""
    def fn(step):
        t = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        exponent = t * num_workers / (2.0 * max(steps_per_epoch, 1))
        return jnp.asarray(gamma0, jnp.float32) * jnp.power(beta, exponent)
    return fn


def linear_anneal(lr: float, total_steps: int, anneal_from: int) -> Schedule:
    """Constant lr, then linearly annealed to 0 (paper A.1 MNIST recipe)."""
    def fn(step):
        t = jnp.asarray(step, jnp.float32)
        frac = jnp.clip((total_steps - t) / max(total_steps - anneal_from, 1),
                        0.0, 1.0)
        return jnp.asarray(lr, jnp.float32) * jnp.where(t < anneal_from, 1.0, frac)
    return fn


def warmup(base: Schedule, warmup_steps: int) -> Schedule:
    if warmup_steps <= 0:
        return base
    def fn(step):
        t = jnp.asarray(step, jnp.float32)
        scale = jnp.clip(t / warmup_steps, 0.0, 1.0)
        return base(step) * scale
    return fn


def from_config(opt_cfg, num_workers: int = 1) -> Schedule:
    """Build the paper-faithful schedule from an OptimizerConfig."""
    gamma0 = opt_cfg.learning_rate
    if opt_cfg.scale_lr_with_workers:
        gamma0 = gamma0 * num_workers          # paper's 0.045*N rule
    if opt_cfg.linear_anneal_steps > 0:
        sched = linear_anneal(gamma0, opt_cfg.linear_anneal_steps,
                              opt_cfg.linear_anneal_from)
    elif opt_cfg.steps_per_epoch > 0:
        sched = exponential_decay(gamma0, opt_cfg.lr_decay_rate,
                                  opt_cfg.steps_per_epoch, num_workers)
    else:
        sched = constant(gamma0)
    return warmup(sched, opt_cfg.warmup_steps)
