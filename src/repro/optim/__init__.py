from repro.optim.optimizers import (Optimizer, adagrad, adam, clip_by_global_norm,
                                    global_norm, make_optimizer, momentum,
                                    rmsprop_momentum, sgd)
from repro.optim import schedules
