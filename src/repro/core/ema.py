"""Exponential moving average of parameters (paper: eval on \\bar theta,
alpha = 0.9999). Kept in f32 regardless of the training dtype."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init(params: Any) -> Any:
    # explicit copy: astype() on an f32 array aliases the input buffer,
    # which breaks donation in jitted train steps
    return jax.tree_util.tree_map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)


def update(ema: Any, params: Any, decay: float) -> Any:
    """ema <- decay * ema + (1 - decay) * params   (paper Alg. 2/4 last line)."""
    d = jnp.asarray(decay, jnp.float32)
    return jax.tree_util.tree_map(
        lambda e, p: d * e + (1.0 - d) * p.astype(jnp.float32), ema, params)


def value(ema: Any, dtype=None) -> Any:
    if dtype is None:
        return ema
    return jax.tree_util.tree_map(lambda e: e.astype(dtype), ema)


def debiased(ema: Any, step: jnp.ndarray, decay: float) -> Any:
    """Bias-corrected EMA for early steps (optional; paper does not debias)."""
    c = 1.0 - jnp.power(jnp.asarray(decay, jnp.float32), step.astype(jnp.float32) + 1)
    return jax.tree_util.tree_map(lambda e: e / c, ema)
