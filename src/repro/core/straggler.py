"""Worker-latency models and straggler statistics (paper §3.1, Figs. 3/4).

The paper measured, on 100 GPU workers, per-iteration gradient arrival
times: most mean times to collect the k-th gradient fall in 1.4–1.8 s, but
the last few grow exponentially (max observed 310 s). We model per-worker
iteration latency as a calibrated mixture:

    T = base + Exp(jitter)                 (healthy worker)
    T = base + Exp(jitter) + Exp(tail)     (w.p. p_tail — preemption /
                                            contention / failing hardware)

which reproduces the flat-then-exponential order-statistic curve. All
sampling is host-side numpy (the mask fed to the SPMD step is data).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


class LatencyModel:
    """sample(rng, (iters, workers)) -> seconds array."""

    def sample(self, rng: np.random.RandomState, shape: Tuple[int, ...]) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PaperCalibrated(LatencyModel):
    """Calibrated to Figs. 3/4: ~1.4s median, exponential tail to ~310s."""

    base: float = 1.3
    jitter: float = 0.12
    p_tail: float = 0.015
    tail: float = 25.0
    cap: float = 310.0

    def sample(self, rng, shape):
        t = self.base + rng.exponential(self.jitter, size=shape)
        straggle = rng.rand(*shape) < self.p_tail
        t = t + straggle * rng.exponential(self.tail, size=shape)
        return np.minimum(t, self.cap)


@dataclasses.dataclass(frozen=True)
class LogNormal(LatencyModel):
    median: float = 1.4
    sigma: float = 0.15

    def sample(self, rng, shape):
        return self.median * np.exp(self.sigma * rng.randn(*shape))


@dataclasses.dataclass(frozen=True)
class DeterministicStragglers(LatencyModel):
    """Specific workers are consistently slow (failing/contended hardware)."""

    base: float = 1.4
    jitter: float = 0.1
    slow_workers: Tuple[int, ...] = ()
    slowdown: float = 5.0

    def sample(self, rng, shape):
        t = self.base + rng.exponential(self.jitter, size=shape)
        for w in self.slow_workers:
            t[..., w] *= self.slowdown
        return t


@dataclasses.dataclass(frozen=True)
class Uniform(LatencyModel):
    lo: float = 1.0
    hi: float = 2.0

    def sample(self, rng, shape):
        return rng.uniform(self.lo, self.hi, size=shape)


# ---------------------------------------------------------------------------
# Order statistics (Figs. 3 and 4)
# ---------------------------------------------------------------------------


def arrival_order_stats(latencies: np.ndarray) -> np.ndarray:
    """latencies: [iters, workers] -> sorted arrival times per iteration."""
    return np.sort(latencies, axis=-1)


def time_to_collect_k(latencies: np.ndarray) -> np.ndarray:
    """[iters, W] -> [iters, W]: time at which the k-th gradient arrived."""
    return arrival_order_stats(latencies)


def mean_median_time_to_k(latencies: np.ndarray):
    """Fig. 4: mean and median (over iterations) of time-to-k, per k."""
    sorted_t = arrival_order_stats(latencies)
    return sorted_t.mean(axis=0), np.median(sorted_t, axis=0)


def cdf_of_time_to_k(latencies: np.ndarray, k: int, grid: np.ndarray):
    """Fig. 3: P(time to collect k-th gradient <= t) over `grid`."""
    tk = arrival_order_stats(latencies)[:, k - 1]
    return np.array([(tk <= t).mean() for t in grid])
