"""Gradient-aggregation strategies — the paper's protocol knobs.

Each strategy turns one iteration's worker arrival times into
  (mask over N+b workers, iteration wall time).

* FullSync           — paper's plain Sync-Opt: wait for everyone.
* BackupWorkers(N,b) — paper Alg. 3/4: first N arrivals count, b dropped.
* Timeout(d)         — paper §6 future work: everything within d of the
                       first arrival counts (>=1 always).
* (Async / SoftSync are event-driven, see repro.core.async_sim.)

The mask is *data* to the SPMD train step: dropped workers still compute
(their cycles are the price of the insurance — identical to the paper,
whose backup workers' gradients are discarded on arrival).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


class Strategy:
    total_workers: int

    def select(self, arrivals: np.ndarray) -> Tuple[np.ndarray, float]:
        """arrivals: [W] seconds -> (mask bool [W], iteration_time)."""
        raise NotImplementedError

    def effective_n(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FullSync(Strategy):
    num_workers: int

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        mask = np.ones_like(arrivals, dtype=bool)
        return mask, float(arrivals.max())

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class BackupWorkers(Strategy):
    """Aggregate the first N of N+b arrivals (paper Alg. 3/4)."""

    num_workers: int          # N
    backups: int              # b

    @property
    def total_workers(self) -> int:
        return self.num_workers + self.backups

    def select(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, kind="stable")
        mask = np.zeros_like(arrivals, dtype=bool)
        mask[order[:n]] = True
        return mask, float(arrivals[order[n - 1]])

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class Timeout(Strategy):
    """Aggregate all gradients arriving within `deadline_s` of the first."""

    num_workers: int
    deadline_s: float

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        t0 = arrivals.min()
        cutoff = t0 + self.deadline_s
        mask = arrivals <= cutoff
        return mask, float(min(arrivals.max(), cutoff))

    def effective_n(self) -> int:
        return self.num_workers     # varies per step; N is the upper bound


def from_config(agg_cfg) -> Strategy:
    s = agg_cfg.strategy
    if s == "full_sync":
        return FullSync(agg_cfg.total_workers)
    if s == "backup":
        return BackupWorkers(agg_cfg.num_workers, agg_cfg.backup_workers)
    if s == "timeout":
        return Timeout(agg_cfg.num_workers, agg_cfg.deadline_s)
    raise ValueError(f"strategy {s!r} is not a synchronous mask strategy")
