"""Gradient-aggregation strategies — the paper's protocol knobs.

Each strategy turns one iteration's worker arrival times into
  (mask over N+b workers, iteration wall time).

* FullSync           — paper's plain Sync-Opt: wait for everyone.
* BackupWorkers(N,b) — paper Alg. 3/4: first N arrivals count, b dropped.
* Timeout(d)         — paper §6 future work: everything within d of the
                       first arrival counts (>=1 always).
* (Async / SoftSync are event-driven, see repro.core.async_sim.)

The mask is *data* to the SPMD train step: dropped workers still compute
(their cycles are the price of the insurance — identical to the paper,
whose backup workers' gradients are discarded on arrival).

``select`` is the host (numpy) rule; ``select_jax`` is its traceable
counterpart used inside the fused chunked trainer's ``lax.scan`` body
(same semantics, jnp ops, no host sync).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


class Strategy:
    total_workers: int

    def select(self, arrivals: np.ndarray) -> Tuple[np.ndarray, float]:
        """arrivals: [W] seconds -> (mask bool [W], iteration_time)."""
        raise NotImplementedError

    def select_jax(self, arrivals: jnp.ndarray):
        """Traceable select: [W] jnp seconds -> (bool [W], f32 scalar)."""
        raise NotImplementedError

    def select_batch(self, arrivals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized select: [K, W] -> (masks [K, W], times [K]).

        Row i is bitwise-identical to select(arrivals[i]) — the fused
        chunked trainer relies on this for replay-exact equivalence.
        Subclasses override with a vectorized rule; this fallback loops.
        """
        pairs = [self.select(a) for a in arrivals]
        return (np.stack([m for m, _ in pairs]),
                np.array([t for _, t in pairs], np.float64))

    def effective_n(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FullSync(Strategy):
    num_workers: int

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        mask = np.ones_like(arrivals, dtype=bool)
        return mask, float(arrivals.max())

    def select_jax(self, arrivals):
        return jnp.ones(arrivals.shape, dtype=bool), jnp.max(arrivals)

    def select_batch(self, arrivals):
        return (np.ones_like(arrivals, dtype=bool),
                arrivals.max(axis=-1).astype(np.float64))

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class BackupWorkers(Strategy):
    """Aggregate the first N of N+b arrivals (paper Alg. 3/4)."""

    num_workers: int          # N
    backups: int              # b

    @property
    def total_workers(self) -> int:
        return self.num_workers + self.backups

    def select(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, kind="stable")
        mask = np.zeros_like(arrivals, dtype=bool)
        mask[order[:n]] = True
        return mask, float(arrivals[order[n - 1]])

    def select_jax(self, arrivals):
        n = self.num_workers
        order = jnp.argsort(arrivals)        # stable, matching np "stable"
        mask = jnp.zeros(arrivals.shape, dtype=bool).at[order[:n]].set(True)
        return mask, arrivals[order[n - 1]]

    def select_batch(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, axis=-1, kind="stable")
        masks = np.zeros_like(arrivals, dtype=bool)
        np.put_along_axis(masks, order[:, :n], True, axis=-1)
        times = np.take_along_axis(arrivals, order[:, n - 1:n], axis=-1)[:, 0]
        return masks, times.astype(np.float64)

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class Timeout(Strategy):
    """Aggregate all gradients arriving within `deadline_s` of the first."""

    num_workers: int
    deadline_s: float

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        t0 = arrivals.min()
        cutoff = t0 + self.deadline_s
        mask = arrivals <= cutoff
        return mask, float(min(arrivals.max(), cutoff))

    def select_jax(self, arrivals):
        cutoff = jnp.min(arrivals) + self.deadline_s
        return arrivals <= cutoff, jnp.minimum(jnp.max(arrivals), cutoff)

    def select_batch(self, arrivals):
        cutoff = arrivals.min(axis=-1) + self.deadline_s
        masks = arrivals <= cutoff[:, None]
        times = np.minimum(arrivals.max(axis=-1), cutoff)
        return masks, times.astype(np.float64)

    def effective_n(self) -> int:
        return self.num_workers     # varies per step; N is the upper bound


def from_config(agg_cfg) -> Strategy:
    s = agg_cfg.strategy
    if s == "full_sync":
        return FullSync(agg_cfg.total_workers)
    if s == "backup":
        return BackupWorkers(agg_cfg.num_workers, agg_cfg.backup_workers)
    if s == "timeout":
        return Timeout(agg_cfg.num_workers, agg_cfg.deadline_s)
    raise ValueError(f"strategy {s!r} is not a synchronous mask strategy")
