"""Deprecated shim — the mask strategies moved to repro.core.coordination.

``FullSync``/``BackupWorkers``/``Timeout`` (and the ``Strategy`` base)
are re-exported unchanged, so every existing import keeps working.
``from_config`` now delegates to :func:`repro.core.registry.get_strategy`
and emits a ``DeprecationWarning`` once per process; like the original it
only hands back synchronous mask strategies (event regimes raise).
"""
from __future__ import annotations

from repro.core import registry as _registry
from repro.core.coordination import (BackupWorkers, FullSync,   # noqa: F401
                                     MaskStrategy, Strategy, Timeout,
                                     warn_once)


def from_config(agg_cfg) -> Strategy:
    warn_once("aggregation.from_config",
              "repro.core.aggregation.from_config is deprecated; use "
              "repro.core.registry.get_strategy(cfg) instead")
    strategy = _registry.get_strategy(agg_cfg)
    if strategy.kind != "mask":
        raise ValueError(
            f"strategy {agg_cfg.strategy!r} is not a synchronous mask "
            f"strategy")
    return strategy
