"""Seeded chaos engine: composable, deterministic fault schedules.

The paper's whole argument is that synchronous SGD with backup workers
survives stragglers and failures — this module turns the repo's ad-hoc
``kill_worker_at={step: worker}`` dict into a real fault-injection layer
(docs/robustness.md):

* :class:`FaultEvent` — one planned fault. The taxonomy (``FAULT_KINDS``):

    - ``crash``     worker dies; its gradient never arrives again (the
                    SPMD engine masks its shard out of the
                    ``backup_reduce`` + psum collective until the next
                    rescale boundary).
    - ``slowdown``  transient straggler spike: the worker's arrival
                    latencies are multiplied by ``factor`` for
                    ``duration`` steps (``StragglerSimulator.slowdown``
                    in mask mode; ``EventScheduler`` service-time scaling
                    in event mode).
    - ``restart``   a crashed worker rejoins with the *current* params
                    (fresh read copy, next arrival scheduled now).
    - ``ckpt_io``   the next checkpoint save fails ``fails`` times with
                    ``OSError`` before succeeding — exercising the
                    retry-with-backoff path in ``train.checkpoint.save``.
    - ``preempt``   preemption notice: an optional grace-period
                    checkpoint is committed, then the run dies with
                    :class:`Preemption` — the recovery supervisor's job.

* :class:`FaultPlan` — an ordered, seeded schedule of events, built from
  a spec string (:func:`plan_from_spec`) or explicit events. Same seed
  and spec ⇒ identical plan ⇒ identical recovery log.

* :class:`FaultInjector` — the runtime: tracks which events have fired
  (faults fire at most once — a restored run does not replay already-
  injected faults, but their persistent effects re-sync), the permanent
  dead set, active slowdown windows, armed checkpoint failures, and the
  structured recovery log threaded into ``TrainResult.recovery_log``.

Faults are applied at chunk boundaries (the Trainer forces a boundary at
every pending fault step, exactly as it does for kill/checkpoint steps),
so the engine composes with all three backends: the host sim, the fused
event scan, and the SPMD mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("crash", "slowdown", "restart", "ckpt_io", "preempt")

# recovery-log event types (schema in docs/api.md); every entry also
# carries "step" and the fields listed per type
RECOVERY_EVENTS = ("worker_crash", "worker_slowdown", "worker_restart",
                   "ckpt_io_fault", "ckpt_write_retry", "preempt",
                   "restore", "rescale", "give_up")


class Preemption(RuntimeError):
    """An injected (or real) preemption notice: the run must die now.

    ``grace_checkpointed`` records whether a grace-period checkpoint was
    committed before raising — the supervisor restores from it."""

    def __init__(self, step: int, grace_checkpointed: bool):
        super().__init__(f"preempted at step {step} "
                         f"(grace checkpoint: {grace_checkpointed})")
        self.step = int(step)
        self.grace_checkpointed = bool(grace_checkpointed)


class InjectedIOError(OSError):
    """The ckpt_io fault's write failure (distinguishable in tests)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One planned fault; fields beyond (kind, step) are kind-specific."""

    kind: str
    step: int
    worker: int = -1          # crash/slowdown/restart target
    factor: float = 4.0       # slowdown latency multiplier
    duration: int = 8         # slowdown steps until recovery
    fails: int = 2            # ckpt_io: failed write attempts injected
    grace: bool = True        # preempt: grace-period checkpoint first
    replica: int = -1         # serving-replica target (router scope, :rN)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS + ("slow_end",):
            raise ValueError(_unknown_kind_message(self.kind))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered fault schedule; deterministic in (spec, seed)."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            sorted(self.events, key=lambda e: (e.step, e.kind, e.worker, e.replica))))

    def __len__(self) -> int:
        return len(self.events)


_KIND_ALIASES = {"slow": "slowdown", "kill": "crash"}


def _unknown_kind_message(kind: str, item: Optional[str] = None) -> str:
    """Mirror ``registry.get_strategy``'s unknown-name message: name what
    was asked for, then the full list of valid kinds (plus aliases)."""
    where = f" in {item!r}" if item else ""
    aliases = ", ".join(f"{a}={k}" for a, k in sorted(_KIND_ALIASES.items()))
    return (f"unknown fault kind {kind!r}{where}; "
            f"valid kinds: {', '.join(FAULT_KINDS)} (aliases: {aliases})")


@dataclasses.dataclass(frozen=True)
class _SpecItem:
    kind: str
    step: Optional[int] = None
    worker: Optional[int] = None
    replica: Optional[int] = None
    factor: Optional[float] = None
    duration: Optional[int] = None
    count: int = 1


def _parse_item(item: str) -> _SpecItem:
    """One spec item -> :class:`_SpecItem`.

    Grammar (docs/robustness.md):
        kind '@' step [':w' worker] [':r' replica]
                      [':x' factor] [':d' duration]   explicit placement
        kind ['=' count]                              seeded-random placement

    ``:rN`` scopes the fault to serving replica N (the router surface,
    docs/serving.md); ``:xF``/``:dD`` override the slowdown factor and
    duration. ``:wN`` and ``:rN`` are mutually exclusive — a fault
    targets a training worker or a serving replica, never both.
    """
    if "@" in item:
        kind, rest = item.split("@", 1)
        parts = rest.split(":")
        fields: Dict[str, float] = {}
        for p in parts[1:]:
            try:
                value = (float(p[1:])
                         if p[:1] in ("w", "r", "x", "d") and p[1:]
                         else None)
            except ValueError:           # known key, non-numeric suffix
                value = None
            if value is None:
                raise ValueError(f"bad fault spec field {p!r} in {item!r} "
                                 f"(valid: wN worker, rN replica, "
                                 f"xF factor, dD duration)")
            if p[0] in fields:
                raise ValueError(f"duplicate fault spec field {p!r} "
                                 f"in {item!r}")
            fields[p[0]] = value
        if "w" in fields and "r" in fields:
            raise ValueError(f"fault {item!r} targets both a worker (:w) "
                             f"and a replica (:r) — pick one scope")
        return _SpecItem(
            _KIND_ALIASES.get(kind.strip(), kind.strip()),
            step=int(parts[0]),
            worker=None if "w" not in fields else int(fields["w"]),
            replica=None if "r" not in fields else int(fields["r"]),
            factor=fields.get("x"),
            duration=None if "d" not in fields else int(fields["d"]))
    kind, _, cnt = item.partition("=")
    return _SpecItem(_KIND_ALIASES.get(kind.strip(), kind.strip()),
                     count=int(cnt) if cnt else 1)


def plan_from_spec(spec: str, *, num_steps: int, num_workers: int,
                   seed: int = 0, num_replicas: int = 0) -> FaultPlan:
    """Parse a chaos spec into a deterministic :class:`FaultPlan`.

    Explicit items pin (step, worker/replica); count items draw
    steps/workers from a RandomState seeded with ``seed`` — the same
    (spec, seed, num_steps, num_workers) always yields the identical
    plan. ``num_replicas > 0`` switches the random-target scope to
    serving replicas (the router's surface): drawn targets land on
    ``replica`` instead of ``worker``, with the identical draw sequence.
    """
    rng = np.random.RandomState(seed)
    hi = max(num_steps - 1, 2)
    events: List[FaultEvent] = []
    for raw in spec.split(","):
        item = raw.strip()
        if not item:
            continue
        it = _parse_item(item)
        if it.kind not in FAULT_KINDS:
            raise ValueError(_unknown_kind_message(it.kind, item))
        for _ in range(it.count):
            s = it.step if it.step is not None else int(rng.randint(1, hi))
            if num_replicas:        # router scope: random targets = replicas
                r = (it.replica if it.replica is not None
                     else int(rng.randint(num_replicas)))
                w = -1 if it.worker is None else int(it.worker)
            else:                   # training scope: legacy draw order
                w = (it.worker if it.worker is not None
                     else int(rng.randint(num_workers)))
                if it.kind in ("ckpt_io", "preempt"):
                    w = -1
                r = -1 if it.replica is None else int(it.replica)
            default_dur = (max(2, min(8, num_steps // 8))
                           if it.kind == "slowdown" else 8)
            events.append(FaultEvent(
                it.kind, s, worker=w, replica=r,
                factor=4.0 if it.factor is None else float(it.factor),
                duration=default_dur if it.duration is None
                else int(it.duration)))
    return FaultPlan(tuple(events), seed)


class FaultInjector:
    """Runtime state of one chaos plan across a (possibly restarted) run.

    The Trainer pulls due events each step via :meth:`take_due` and asks
    :meth:`upcoming_steps` when sizing chunks so every fault lands on a
    dispatch boundary. The supervisor owns the injector across restarts:
    :meth:`resync` re-applies persistent effects (dead workers, active
    slowdowns) to a freshly rebuilt Trainer.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.log: List[Dict] = []
        self._pending: List[FaultEvent] = list(plan.events)
        self.dead: set = set()              # permanently-crashed workers
        self.slow_active: Dict[int, Tuple[float, int]] = {}  # w -> (f, end)
        self.ckpt_fails_armed = 0
        self._ckpt_io_step = 0              # step the arming happened at

    # -- schedule queries ----------------------------------------------------

    def upcoming_steps(self) -> List[int]:
        """Steps that must be chunk boundaries: every unfired fault plus
        the end of every active slowdown window."""
        steps = [e.step for e in self._pending]
        steps += [end for _, end in self.slow_active.values()]
        return steps

    def take_due(self, step: int) -> List[FaultEvent]:
        """Pop every event due at or before ``step`` (fire-at-most-once),
        appending synthesized ``slow_end`` events for expired windows."""
        due = [e for e in self._pending if e.step <= step]
        self._pending = [e for e in self._pending if e.step > step]
        for w, (factor, end) in sorted(self.slow_active.items()):
            if end <= step:
                due.append(FaultEvent("slow_end", end, worker=w,
                                      factor=factor))
        due.sort(key=lambda e: (e.step, e.kind, e.worker, e.replica))
        return due

    def defer(self, event: FaultEvent, to_step: int) -> None:
        """Push an event back (e.g. a preempt that cannot checkpoint at a
        mid-window arrival) — deterministic, so logs stay reproducible."""
        self._pending.append(dataclasses.replace(event, step=int(to_step)))
        self._pending.sort(key=lambda e: (e.step, e.kind, e.worker, e.replica))

    # -- effect bookkeeping (the Trainer calls these as it applies) ----------

    def record(self, event: str, **fields) -> None:
        entry = {"event": event, **fields}
        self.log.append(entry)

    def note_crash(self, step: int, worker: int) -> None:
        self.dead.add(int(worker))
        self.slow_active.pop(int(worker), None)
        self.record("worker_crash", step=int(step), worker=int(worker))

    def note_slowdown(self, step: int, worker: int, factor: float,
                      duration: int) -> int:
        end = int(step + max(duration, 1))
        self.slow_active[int(worker)] = (float(factor), end)
        self.record("worker_slowdown", step=int(step), worker=int(worker),
                    factor=float(factor), until=end)
        return end

    def note_slow_end(self, worker: int) -> None:
        self.slow_active.pop(int(worker), None)

    def note_restart(self, step: int, worker: int) -> None:
        self.dead.discard(int(worker))
        self.record("worker_restart", step=int(step), worker=int(worker))

    def arm_ckpt_failures(self, step: int, fails: int) -> None:
        self.ckpt_fails_armed += int(fails)
        self._ckpt_io_step = int(step)
        self.record("ckpt_io_fault", step=int(step), fails=int(fails))

    def ckpt_io_check(self) -> None:
        """``checkpoint.save``'s per-attempt hook: raise while armed."""
        if self.ckpt_fails_armed > 0:
            self.ckpt_fails_armed -= 1
            raise InjectedIOError(
                f"injected checkpoint write failure "
                f"(armed at step {self._ckpt_io_step})")

    def on_ckpt_retry(self, step: int):
        """A ``checkpoint.save(on_retry=...)`` callback bound to ``step``."""
        def cb(attempt: int, exc: BaseException) -> None:
            self.record("ckpt_write_retry", step=int(step),
                        attempt=int(attempt), error=type(exc).__name__)
        return cb

    # -- supervisor hooks -----------------------------------------------------

    def resync(self, trainer) -> None:
        """Re-apply persistent fault effects to a rebuilt Trainer (after a
        supervisor restore): permanent deaths and still-active slowdowns.
        Idempotent; emits no log entries."""
        for w in sorted(self.dead):
            trainer.fault_kill(w)
        for w, (factor, end) in sorted(self.slow_active.items()):
            if end > trainer.step:
                trainer.fault_slowdown(w, factor)
            else:
                self.slow_active.pop(w, None)


def build_injector(fault_cfg, *, num_steps: int,
                   num_workers: int) -> Optional[FaultInjector]:
    """FaultConfig -> FaultInjector (None when no chaos is configured)."""
    if fault_cfg is None or not fault_cfg.spec:
        return None
    plan = plan_from_spec(fault_cfg.spec, num_steps=num_steps,
                          num_workers=num_workers, seed=fault_cfg.seed)
    return FaultInjector(plan)
