"""Discrete-event iteration timing for synchronous strategies.

Composes a latency model with an aggregation strategy to produce per-step
worker masks and iteration times — the host-side driver feeding the SPMD
train step, and the machinery behind Figs. 4/6 (estimated time to converge
for each (N, b) split of a fixed machine budget).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.core.aggregation import BackupWorkers, Strategy
from repro.core.straggler import LatencyModel, PaperCalibrated


@dataclasses.dataclass
class StepEvent:
    step: int
    mask: np.ndarray          # [W] bool — workers whose gradients count
    iteration_time: float     # simulated seconds for this step
    arrivals: np.ndarray      # [W] raw latencies


@dataclasses.dataclass
class ChunkEvents:
    """K consecutive StepEvents stacked for one fused-loop dispatch."""

    start_step: int
    masks: np.ndarray         # [K, W] bool
    times: np.ndarray         # [K] f64 per-step iteration times
    arrivals: np.ndarray      # [K, W] raw latencies

    def __len__(self) -> int:
        return self.masks.shape[0]


class StragglerSimulator:
    """Yields one StepEvent per training step; deterministic in seed.

    ``dead`` workers (failure injection) never arrive: latency = +inf. For
    BackupWorkers, as long as alive >= N the protocol absorbs failures with
    zero downtime — the elastic layer only kicks in below that.
    """

    def __init__(self, strategy: Strategy, latency: Optional[LatencyModel] = None,
                 seed: int = 0, start_step: int = 0):
        self.strategy = strategy
        self.latency = latency or PaperCalibrated()
        self.seed = seed
        self.dead = np.zeros(strategy.total_workers, dtype=bool)
        # chaos engine's transient straggler spikes: per-worker latency
        # multipliers applied AFTER sampling, so the underlying RandomState
        # streams (the replay contract) are untouched by fault injection
        self.slowdown = np.ones(strategy.total_workers, dtype=np.float64)
        self._step = start_step

    def kill_worker(self, w: int) -> None:
        self.dead[w] = True

    def revive_worker(self, w: int) -> None:
        self.dead[w] = False

    def set_slowdown(self, w: int, factor: float) -> None:
        """Transient slowdown spike (factor=1.0 restores health)."""
        self.slowdown[w] = float(factor)

    @property
    def step(self) -> int:
        return self._step

    def reset_to_step(self, step: int) -> None:
        """Align the simulator with a restored/advanced trainer step.

        Sampling is deterministic in (seed, step), so this is the whole
        replay-exact resume contract: no other simulator state to restore.
        """
        self._step = int(step)

    @property
    def alive(self) -> int:
        return int((~self.dead).sum())

    def _raw_arrivals(self, step: int) -> np.ndarray:
        """Per-step latencies, deterministic in (seed, step) — the single
        definition of the replay contract (next_event and next_events must
        stay bit-identical)."""
        rng = np.random.RandomState((self.seed * 1_000_003 + step)
                                    % (2 ** 31 - 1))
        return self.latency.sample(rng, (self.strategy.total_workers,))

    def next_event(self) -> StepEvent:
        # deterministic in (seed, step): checkpoint/resume replays the
        # exact arrival sequence with no simulator state to persist
        arrivals = self._raw_arrivals(self._step) * self.slowdown
        arrivals = np.where(self.dead, np.inf, arrivals)
        mask, t = self.strategy.select(arrivals)
        mask = mask & ~self.dead
        ev = StepEvent(self._step, mask, t, arrivals)
        self._step += 1
        return ev

    def next_events(self, k: int) -> ChunkEvents:
        """The next k events stacked — bit-identical to k next_event() calls.

        Sampling keeps the per-step RandomState streams (replay contract);
        dead-masking and selection run vectorized over the [K, W] block via
        Strategy.select_batch (row-wise identical to select)."""
        start = self._step
        arrivals = np.empty((k, self.strategy.total_workers))
        for i in range(k):
            arrivals[i] = self._raw_arrivals(self._step)
            self._step += 1
        arrivals = arrivals * self.slowdown[None, :]
        arrivals = np.where(self.dead[None, :], np.inf, arrivals)
        masks, times = self.strategy.select_batch(arrivals)
        masks = masks & ~self.dead[None, :]
        return ChunkEvents(start, masks, times, arrivals)

    def __iter__(self) -> Iterator[StepEvent]:
        while True:
            yield self.next_event()


def mean_iteration_time(strategy: Strategy, latency: LatencyModel,
                        iters: int = 1000, seed: int = 0) -> float:
    sim = StragglerSimulator(strategy, latency, seed)
    return float(np.mean([sim.next_event().iteration_time for _ in range(iters)]))


def estimate_time_to_converge(n_values: np.ndarray, iters_to_converge: np.ndarray,
                              total_machines: int, latency: LatencyModel,
                              sim_iters: int = 2000, seed: int = 0
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """Paper Fig. 6: for each N (with b = total - N), estimated convergence
    time = iterations(N) x mean iteration time of BackupWorkers(N, b).

    iters_to_converge: measured/interpolated iterations for each N.
    Returns (times [len(n_values)], mean_step_time [len(n_values)]).
    """
    times, step_times = [], []
    for n, it in zip(n_values, iters_to_converge):
        st = mean_iteration_time(BackupWorkers(int(n), total_machines - int(n)),
                                 latency, sim_iters, seed)
        step_times.append(st)
        times.append(st * it)
    return np.array(times), np.array(step_times)
