"""String-keyed strategy registry: ``AggregationConfig`` -> strategy.

``get_strategy(cfg)`` is the single construction path from config to a
:class:`repro.core.coordination.CoordinationStrategy` — it replaces the
hand-rolled ``aggregation.from_config`` dispatch and covers every regime
the paper compares (plus the §2.1 staleness rig). New regimes register
with one decorator, so hybrid/hierarchical schemes (Jin et al. 2016;
arXiv:2407.00101) land as one-file plugins:

    @register("my_regime")
    def _build(cfg: AggregationConfig) -> CoordinationStrategy:
        return MyRegime(cfg.num_workers, ...)

Event-strategy plugins that additionally implement the chunked
plan/scan protocol (``plan_arrival`` + ``on_arrival_scan``, advertised
via ``scan_supported = True``) get the fused device-resident event
engine for free at ``chunk_size > 1``; :func:`supports_event_scan` is
how the Trainer decides whether the fused path is available.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.core import coordination

_BUILDERS: Dict[str, Callable] = {}


def register(name: str) -> Callable:
    """Decorator: register a builder(cfg) -> CoordinationStrategy."""

    def deco(fn: Callable) -> Callable:
        _BUILDERS[name] = fn
        return fn

    return deco


def available() -> List[str]:
    return sorted(_BUILDERS)


def supports_spmd(strategy: coordination.CoordinationStrategy,
                  exec_cfg=None) -> bool:
    """True when the strategy can run on the SPMD execution engine
    (``repro.distributed.spmd_engine`` — workers over a real mesh axis).
    Any mask strategy qualifies by default: the engine consumes the same
    host-planned masks as the simulated backend, so ``select`` /
    ``select_batch`` are all it needs. Plugins that bake single-device
    assumptions into their selection can opt out with a class attribute
    ``spmd_supported = False``; event strategies (host-scheduled
    per-arrival control flow) are never SPMD-executable.

    When an ``ExecutionConfig`` with ``mesh_model > 1`` is passed, the
    strategy must additionally allow tensor-parallel execution (params /
    opt state / EMA sharded over the mesh 'model' axis — docs/spmd.md).
    Every built-in mask strategy does: masks are per-worker *data*, so
    the parameter layout is invisible to selection. Plugins whose
    selection inspects parameter values can opt out of just the sharded
    path with ``spmd_tp_supported = False`` while keeping plain
    (replicated) SPMD support. The Trainer falls back to the simulated
    backend (with a warning) when this returns False — it never errors."""
    ok = (getattr(strategy, "kind", "") == "mask"
          and bool(getattr(strategy, "spmd_supported", True)))
    if ok and exec_cfg is not None and getattr(exec_cfg, "mesh_model", 1) > 1:
        ok = bool(getattr(strategy, "spmd_tp_supported", True))
    return ok


def supports_event_scan(strategy: coordination.CoordinationStrategy) -> bool:
    """True when an event strategy implements the chunked plan/scan
    protocol (``plan_arrival`` host half + ``on_arrival_scan`` device
    half) required by the fused event engine (``chunk_size > 1``).
    Third-party plugins that only implement ``on_arrival`` still run on
    the legacy per-arrival path at ``chunk_size=1``."""
    return (getattr(strategy, "kind", "") == "event"
            and bool(getattr(strategy, "scan_supported", False)))


def get_strategy(agg_cfg) -> coordination.CoordinationStrategy:
    """Build the strategy named by ``agg_cfg.strategy``.

    The only construction path used by the Trainer (tested); unknown
    names fail with the full list of valid ones.
    """
    try:
        builder = _BUILDERS[agg_cfg.strategy]
    except KeyError:
        raise ValueError(
            f"unknown coordination strategy {agg_cfg.strategy!r}; "
            f"valid strategies: {', '.join(available())}") from None
    return builder(agg_cfg)


@register("full_sync")
def _full_sync(cfg) -> coordination.FullSync:
    return coordination.FullSync(cfg.total_workers)


@register("backup")
def _backup(cfg) -> coordination.BackupWorkers:
    return coordination.BackupWorkers(cfg.num_workers, cfg.backup_workers)


@register("timeout")
def _timeout(cfg) -> coordination.Timeout:
    return coordination.Timeout(cfg.num_workers, cfg.deadline_s)


@register("dynamic_backup")
def _dynamic_backup(cfg) -> coordination.DynamicBackup:
    return coordination.DynamicBackup(
        cfg.num_workers, cfg.backup_workers, cfg.dynamic_window,
        cfg.dynamic_min_workers,
        latency_source=getattr(cfg, "latency_source", "sim"))


@register("async")
def _async(cfg) -> coordination.Async:
    return coordination.Async(cfg.num_workers)


@register("softsync")
def _softsync(cfg) -> coordination.SoftSync:
    return coordination.SoftSync(cfg.num_workers, cfg.softsync_c)


@register("staleness")
def _staleness(cfg) -> coordination.Staleness:
    return coordination.Staleness(cfg.staleness_tau, cfg.staleness_ramp_steps,
                                  cfg.staleness_jitter)
