"""One coordination API for every regime the paper compares.

The paper's whole argument is a comparison — Async-Opt (Alg. 1/2) vs
Sync-Opt vs Sync-Opt with backup workers (Alg. 3/4) — so every regime
lives behind a single ``CoordinationStrategy`` protocol with two families:

* **Mask strategies** (``kind == "mask"``): one SPMD step per iteration.
  The strategy turns one iteration's worker arrival times into
  ``(mask over W workers, iteration wall time)``; the mask is *data* to
  the jitted train step (dropped workers still compute — their cycles are
  the price of the insurance, exactly as in the paper, whose backup
  workers' gradients are discarded on arrival).

    - ``FullSync``            paper's plain Sync-Opt: wait for everyone.
    - ``BackupWorkers(N, b)`` paper Alg. 3/4: first N arrivals count.
    - ``Timeout(d)``          paper §6 future work: everything within d
                              of the first arrival counts (>=1 always).

  ``select`` is the host (numpy) rule; ``select_jax`` is its traceable
  counterpart used inside the fused chunked trainer's ``lax.scan`` body;
  ``select_batch`` is the vectorized [K, W] form (row-wise bit-identical
  to ``select`` — the chunked trainer's replay contract).

* **Event strategies** (``kind == "event"``): a discrete-event scheduler
  pops gradient *arrivals* one at a time (per the shared ``LatencyModel``)
  and the strategy decides, per arrival, whether a parameter-server
  update applies (``on_arrival``):

    - ``Async``        paper Alg. 1/2: every arrival applies immediately,
                       stale by however many updates landed since the
                       worker read its parameter copy.
    - ``SoftSync(c)``  Zhang et al. (2015b): average every c arrivals,
                       then apply (stale gradients allowed — contrast
                       with the paper's hard drop).
    - ``Staleness``    paper §2.1's controlled rig: serial SGD applying
                       the gradient from tau steps ago (old-gradient
                       buffer + the paper's ramp-up trick); tau=0 is
                       bit-exact serial SGD.

Strategies are constructed from ``AggregationConfig`` by the string-keyed
registry in :mod:`repro.core.registry` (``get_strategy(cfg)``) — the only
construction path the Trainer uses. ``repro.train.loop.Trainer`` executes
both families, so async/softsync get checkpoint/resume, EMA, failure
injection, and the unified per-update metrics schema
``(step, loss, sim_time, selected, staleness)`` for free; see docs/api.md.

The functional engine ``run_events`` is the faithful port of the legacy
``async_sim.simulate_*`` discrete-event loops (same RandomState draw
order, same heap discipline), so the deprecated shims delegate here and
stay bit-exact.

**The chunked event engine** (docs/perf.md "Event engine"): because the
apply-or-buffer verdict of every built-in event strategy depends only on
the arrival sequence and per-arrival counters — never on the gradient
values — the host can cheaply precompute a block of K arrivals into flat
arrays (``plan_events`` → :class:`EventPlan`), and a single ``lax.scan``
(``repro.train.train_step.build_event_chunk_step``) then runs gradient
computation, strategy application, optimizer update and EMA entirely on
device. Each strategy exposes the host half as ``plan_arrival`` and the
traceable half as ``on_arrival_scan``; the plan replays ``run_events``'
exact update/staleness sequence (parity-tested in
tests/test_event_scan.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ema as ema_lib
from repro.core.straggler import LatencyModel, PaperCalibrated


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


class CoordinationStrategy:
    """Base of every coordination regime.

    ``kind`` selects the Trainer execution mode: ``"mask"`` runs one SPMD
    step per iteration with a worker mask; ``"event"`` runs the
    discrete-event parameter-server loop. ``total_workers`` is the number
    of machines launched (N + b for backup workers).
    """

    kind: str = ""
    name: str = ""
    total_workers: int


class MaskStrategy(CoordinationStrategy):
    """Synchronous regimes: arrival times -> (worker mask, step time).

    ``spmd_supported`` — True (the default) when the strategy's masks are
    pure per-step data, so the SPMD execution engine can run it over a
    real device mesh unchanged (``registry.supports_spmd``). Plugins
    whose selection assumes single-device execution opt out by setting
    it False; the Trainer then falls back to the simulated backend.
    """

    kind = "mask"
    spmd_supported = True

    def select(self, arrivals: np.ndarray) -> Tuple[np.ndarray, float]:
        """arrivals: [W] seconds -> (mask bool [W], iteration_time)."""
        raise NotImplementedError

    def select_jax(self, arrivals: jnp.ndarray):
        """Traceable select: [W] jnp seconds -> (bool [W], f32 scalar)."""
        raise NotImplementedError

    def select_batch(self, arrivals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized select: [K, W] -> (masks [K, W], times [K]).

        Row i is bitwise-identical to select(arrivals[i]) — the fused
        chunked trainer relies on this for replay-exact equivalence.
        Subclasses override with a vectorized rule; this fallback loops.
        """
        pairs = [self.select(a) for a in arrivals]
        return (np.stack([m for m, _ in pairs]),
                np.array([t for _, t in pairs], np.float64))

    def effective_n(self) -> int:
        raise NotImplementedError


# Back-compat alias: the pre-registry name for the mask base class.
Strategy = MaskStrategy


@dataclasses.dataclass(frozen=True)
class FullSync(MaskStrategy):
    num_workers: int

    name = "full_sync"

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        mask = np.ones_like(arrivals, dtype=bool)
        return mask, float(arrivals.max())

    def select_jax(self, arrivals):
        return jnp.ones(arrivals.shape, dtype=bool), jnp.max(arrivals)

    def select_batch(self, arrivals):
        return (np.ones_like(arrivals, dtype=bool),
                arrivals.max(axis=-1).astype(np.float64))

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class BackupWorkers(MaskStrategy):
    """Aggregate the first N of N+b arrivals (paper Alg. 3/4)."""

    num_workers: int          # N
    backups: int              # b

    name = "backup"

    @property
    def total_workers(self) -> int:
        return self.num_workers + self.backups

    def select(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, kind="stable")
        mask = np.zeros_like(arrivals, dtype=bool)
        mask[order[:n]] = True
        return mask, float(arrivals[order[n - 1]])

    def select_jax(self, arrivals):
        n = self.num_workers
        order = jnp.argsort(arrivals)        # stable, matching np "stable"
        mask = jnp.zeros(arrivals.shape, dtype=bool).at[order[:n]].set(True)
        return mask, arrivals[order[n - 1]]

    def select_batch(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, axis=-1, kind="stable")
        masks = np.zeros_like(arrivals, dtype=bool)
        np.put_along_axis(masks, order[:, :n], True, axis=-1)
        times = np.take_along_axis(arrivals, order[:, n - 1:n], axis=-1)[:, 0]
        return masks, times.astype(np.float64)

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class Timeout(MaskStrategy):
    """Aggregate all gradients arriving within `deadline_s` of the first."""

    num_workers: int
    deadline_s: float

    name = "timeout"

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        t0 = arrivals.min()
        cutoff = t0 + self.deadline_s
        mask = arrivals <= cutoff
        return mask, float(min(arrivals.max(), cutoff))

    def select_jax(self, arrivals):
        cutoff = jnp.min(arrivals) + self.deadline_s
        return arrivals <= cutoff, jnp.minimum(jnp.max(arrivals), cutoff)

    def select_batch(self, arrivals):
        cutoff = arrivals.min(axis=-1) + self.deadline_s
        masks = arrivals <= cutoff[:, None]
        times = np.minimum(arrivals.max(axis=-1), cutoff)
        return masks, times.astype(np.float64)

    def effective_n(self) -> int:
        return self.num_workers     # varies per step; N is the upper bound


@dataclasses.dataclass
class DynamicBackup(MaskStrategy):
    """Adaptive backup cutoff (Dynamic Backup Workers, arXiv:2102.06280).

    Runs the paper's backup-worker protocol but re-estimates the
    aggregation cutoff n online: after every step the sorted arrival
    vector joins a sliding window of the last ``window`` steps, and n is
    reset to the argmax of the throughput objective

        n / E[t_(n)]        (gradients aggregated per simulated second),

    where E[t_(n)] is the windowed mean of the n-th order statistic of
    the arrival times. A heavy straggler tail pushes n down (cut the
    tail, keep throughput); a healthy cluster pushes n back up toward
    full sync. Dead workers arrive at +inf, so every infeasible n
    (beyond the live count) has infinite expected wait and zero
    throughput — the estimator routes around crashes with no special
    casing. ``min_workers`` floors n (gradient-noise guard).

    Stateful across steps, so unlike the frozen built-ins it exposes
    ``state_dict``/``load_state_dict`` (persisted in checkpoint metadata)
    and opts out of the device straggler backend — selection must run on
    the host where the window lives (``device_select_supported``).
    ``min_alive`` tells the Trainer's elastic layer the true liveness
    floor: the protocol degrades gracefully until fewer than
    ``min_workers`` machines remain.
    """

    num_workers: int          # initial n (= paper's N)
    backups: int              # b — total_workers = N + b
    window: int = 32
    min_workers: int = 0      # floor for the adapted n (0 -> 1)
    latency_source: str = "sim"   # sim | measured

    name = "dynamic_backup"
    device_select_supported = False

    def __post_init__(self):
        if self.latency_source not in ("sim", "measured"):
            raise ValueError(
                f"latency_source must be 'sim' or 'measured' "
                f"(got {self.latency_source!r})")
        self.n = int(self.num_workers)
        self.history: List[np.ndarray] = []   # sorted arrival rows [W]
        # measured mode: the window adapts from fenced wall-clock rows
        # the trainer feeds via observe_measured (repro.obs), not from
        # the simulator's arrival model seen in select()
        self.measured = None
        if self.latency_source == "measured":
            from repro.obs.latency import EmpiricalLatencyModel
            self.measured = EmpiricalLatencyModel(
                self.total_workers, window=max(self.window * 8, 64))

    @property
    def total_workers(self) -> int:
        return self.num_workers + self.backups

    @property
    def min_alive(self) -> int:
        return max(self.min_workers, 1)

    def select(self, arrivals):
        # clamp to the live count: right after a crash (before the window
        # has seen it) the adapted n may exceed the finite arrivals
        n = max(1, min(self.n, int(np.isfinite(arrivals).sum()) or 1))
        order = np.argsort(arrivals, kind="stable")
        mask = np.zeros_like(arrivals, dtype=bool)
        mask[order[:n]] = True
        t = float(arrivals[order[n - 1]])
        if self.latency_source == "sim":
            self._observe(arrivals)
        return mask, t

    # select_batch: the MaskStrategy fallback loops over select — required
    # here, because each row must fold into the window before the next
    # row's cutoff is chosen (the adaptation is inherently sequential).

    def effective_n(self) -> int:
        return self.n

    def _observe(self, arrivals: np.ndarray) -> None:
        self.history.append(np.sort(np.asarray(arrivals, np.float64)))
        if len(self.history) > self.window:
            self.history.pop(0)
        h = np.stack(self.history)                   # [H, W] sorted rows
        with np.errstate(invalid="ignore"):
            mean_t = h.mean(axis=0)                  # E[t_(n)], n = 1..W
        ns = np.arange(1, h.shape[1] + 1, dtype=np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            throughput = np.where(np.isfinite(mean_t), ns / mean_t, 0.0)
        floor = max(self.min_workers, 1)
        throughput[:floor - 1] = -np.inf
        self.n = int(np.argmax(throughput)) + 1

    def observe_measured(self, times: np.ndarray) -> None:
        """Fold one *measured* per-worker step-time row (seconds; +inf
        for dead workers) — the trainer's fenced wall-clock feed in
        ``latency_source='measured'`` mode. The row both joins the
        cutoff-adaptation window (same estimator as sim mode, real
        data) and accumulates in the :class:`EmpiricalLatencyModel`,
        which checkpoints with the strategy and can later stand in for
        a simulated latency model."""
        if self.latency_source != "measured":
            raise RuntimeError(
                "observe_measured is only valid with "
                "latency_source='measured'")
        times = np.asarray(times, np.float64)
        self.measured.record(times)
        self._observe(times)

    # -- checkpointable state (saved as manifest "strategy_state") ----------

    def state_dict(self) -> Dict:
        d = {"n": int(self.n),
             "history": [[float(x) for x in row] for row in self.history],
             "latency_source": self.latency_source}
        if self.measured is not None:
            d["measured"] = self.measured.state_dict()
        return d

    def load_state_dict(self, d: Dict) -> None:
        self.n = int(d["n"])
        self.history = [np.asarray(row, np.float64) for row in d["history"]]
        # pre-telemetry checkpoints carry neither key: stay in sim mode
        if self.measured is not None and d.get("measured") is not None:
            self.measured.load_state_dict(d["measured"])


# ---------------------------------------------------------------------------
# Event side: scheduler + strategies
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Arrival:
    """One gradient arrival popped from the event scheduler."""

    index: int          # arrival counter (0, 1, 2, ...)
    worker: int
    time: float         # simulated seconds (arrival index for serial rigs)
    staleness: int      # updates applied since this worker read its params
    version: int        # PS update count at arrival time


@dataclasses.dataclass
class ReadyUpdate:
    """on_arrival's verdict when a PS update should apply now."""

    grads: Any          # aggregated gradient tree to apply
    staleness: float    # staleness of this update (mean over contributors)
    selected: int       # gradients aggregated into this update


def encode_rng(rng: Optional[np.random.RandomState]) -> Optional[Dict]:
    """JSON-able snapshot of an MT19937 RandomState (checkpoint meta)."""
    if rng is None:
        return None
    key, pos, has_gauss, cached = rng.get_state()[1:]
    return {"key": [int(x) for x in key], "pos": int(pos),
            "has_gauss": int(has_gauss), "cached": float(cached)}


def decode_rng(rng: np.random.RandomState, d: Dict) -> None:
    rng.set_state(("MT19937", np.array(d["key"], np.uint32), int(d["pos"]),
                   int(d["has_gauss"]), float(d["cached"])))


class EventScheduler:
    """The legacy discrete-event queue, extracted and checkpointable.

    Faithful port of the ``async_sim.simulate_*`` RNG discipline: one
    ``latency.sample(rng, (W,))`` draw at construction, then one
    ``latency.sample(rng, (1,))`` draw per re-scheduled worker — so every
    caller (deprecated shims, ``run_events``, the Trainer's event mode)
    replays the identical arrival sequence for the same (latency, seed).
    """

    def __init__(self, num_workers: int, latency: LatencyModel, seed: int):
        self.latency = latency
        self.rng = np.random.RandomState(seed)
        first = self.latency.sample(self.rng, (num_workers,))
        self.queue: List[Tuple[float, int]] = [
            (float(first[w]), w) for w in range(num_workers)]
        heapq.heapify(self.queue)
        # chaos engine's transient straggler spikes: per-worker service-time
        # multipliers applied AFTER sampling, so the RNG draw order (the
        # replay contract) is untouched by fault injection
        self.slowdown: Dict[int, float] = {}

    def pop(self) -> Tuple[float, int]:
        return heapq.heappop(self.queue)

    def push(self, t: float, worker: int) -> None:
        """Reschedule `worker`'s next arrival after its current one at `t`."""
        dt = float(self.latency.sample(self.rng, (1,))[0])
        dt *= self.slowdown.get(worker, 1.0)
        heapq.heappush(self.queue, (t + dt, worker))

    def drop_worker(self, worker: int) -> None:
        """Failure injection: the worker's gradient never arrives again."""
        self.queue = [e for e in self.queue if e[1] != worker]
        heapq.heapify(self.queue)

    def set_slowdown(self, worker: int, factor: float) -> None:
        """Transient slowdown spike (factor=1.0 restores health)."""
        if factor == 1.0:
            self.slowdown.pop(worker, None)
        else:
            self.slowdown[worker] = float(factor)

    def revive_worker(self, worker: int, t: float) -> None:
        """A restarted worker rejoins: its next arrival is scheduled one
        freshly-sampled service time after ``t`` (the revive clock)."""
        dt = float(self.latency.sample(self.rng, (1,))[0])
        dt *= self.slowdown.get(worker, 1.0)
        heapq.heappush(self.queue, (float(t) + dt, worker))

    # -- checkpointable state -------------------------------------------------

    def state_dict(self) -> Dict:
        return {"queue": [[t, int(w)] for t, w in self.queue],
                "rng": encode_rng(self.rng),
                "slowdown": {str(w): f for w, f in self.slowdown.items()}}

    def load_state_dict(self, d: Dict) -> None:
        self.queue = [(float(t), int(w)) for t, w in d["queue"]]
        heapq.heapify(self.queue)
        decode_rng(self.rng, d["rng"])
        # absent in pre-chaos checkpoints: no active spikes
        self.slowdown = {int(w): float(f)
                         for w, f in d.get("slowdown", {}).items()}


class SerialScheduler:
    """Degenerate clock for serial rigs (the §2.1 staleness experiment):
    one logical worker arriving at t = 0, 1, 2, ..."""

    def __init__(self):
        self.t = 0

    def pop(self) -> Tuple[float, int]:
        t = self.t
        self.t += 1
        return float(t), 0

    def push(self, t: float, worker: int) -> None:
        pass

    def drop_worker(self, worker: int) -> None:
        raise ValueError("serial rigs have a single logical worker; "
                         "failure injection does not apply")

    def state_dict(self) -> Dict:
        return {"t": int(self.t)}

    def load_state_dict(self, d: Dict) -> None:
        self.t = int(d["t"])


class EventStrategy(CoordinationStrategy):
    """Asynchronous regimes: a per-arrival apply-or-buffer policy.

    ``uses_clock``          — False for serial rigs (SerialScheduler).
    ``stals_per_arrival``   — legacy AsyncResult.staleness records one
                              entry per *arrival* (async/softsync) vs per
                              *update* (staleness rig).
    ``losses_per_arrival``  — likewise for AsyncResult.losses.
    ``scan_supported``      — True when the strategy implements the
                              chunked plan/scan protocol below.

    The chunked protocol splits ``on_arrival`` into a gradient-free host
    half and a traceable device half:

    * ``init_plan_state(seed)`` / ``plan_arrival(plan_state, arrival)``
      run on the host while a chunk is being planned. ``plan_arrival``
      must make the SAME apply-or-buffer decision ``on_arrival`` would
      (same strategy-RNG draw order), but without gradients — it returns
      a :class:`PlanVerdict` of pure bookkeeping.
    * ``init_scan_state(params_like)`` / ``on_arrival_scan(aux, grads,
      row)`` run inside the fused ``lax.scan``. ``aux`` is the strategy's
      device-resident carry (accumulators, ring buffer); ``row`` is one
      row of :meth:`EventPlan.rows`. Returns ``(aux', agg_grads)`` where
      ``agg_grads`` is the gradient tree to apply when ``row["apply"]``
      is set (and unused otherwise).
    """

    kind = "event"
    uses_clock = True
    stals_per_arrival = True
    losses_per_arrival = False
    scan_supported = False

    def init_state(self, seed: int = 0) -> Any:
        """Fresh mutable per-run state (buffers, strategy-local RNG)."""
        return None

    def on_arrival(self, state: Any, grads: Any,
                   arrival: Arrival) -> Optional[ReadyUpdate]:
        """Decide what the arrival of `grads` does to the parameter server."""
        raise NotImplementedError

    # -- chunked plan/scan protocol (host half + device half) -----------------

    def init_plan_state(self, seed: int = 0) -> Any:
        """Gradient-free twin of ``init_state`` for the chunk planner."""
        return None

    def plan_arrival(self, plan_state: Any, arrival: Arrival) -> "PlanVerdict":
        raise NotImplementedError

    def init_scan_state(self, params_like: Any) -> Any:
        """Device-resident aux carry for the fused scan (default: none)."""
        return ()

    def on_arrival_scan(self, aux: Any, grads: Any, row: Dict) -> Tuple[Any, Any]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Async(EventStrategy):
    """Paper Alg. 1/2: every arrival applies immediately (staleness ~ N)."""

    num_workers: int

    name = "async"
    scan_supported = True

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def on_arrival(self, state, grads, arrival):
        return ReadyUpdate(grads, float(arrival.staleness), 1)

    def plan_arrival(self, plan_state, arrival):
        return PlanVerdict(True, float(arrival.staleness), 1)

    def on_arrival_scan(self, aux, grads, row):
        return aux, grads


@dataclasses.dataclass
class _SoftSyncState:
    pending: List[Any] = dataclasses.field(default_factory=list)
    pending_stals: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _SoftSyncPlan:
    """Host half of the softsync window: staleness tags only, no grads."""

    pending_stals: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class SoftSync(EventStrategy):
    """Zhang et al. (2015b): average every c arrivals, then apply."""

    num_workers: int
    c: int = 1

    name = "softsync"
    scan_supported = True

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def init_state(self, seed: int = 0) -> _SoftSyncState:
        return _SoftSyncState()

    def on_arrival(self, state, grads, arrival):
        state.pending.append(grads)
        state.pending_stals.append(arrival.staleness)
        if len(state.pending) < self.c:
            return None
        mean_g = jax.tree_util.tree_map(
            lambda *gs: sum(gs[1:], gs[0]) / len(gs), *state.pending)
        stal = float(np.mean(state.pending_stals))
        n = len(state.pending)
        state.pending = []
        state.pending_stals = []
        return ReadyUpdate(mean_g, stal, n)

    def init_plan_state(self, seed: int = 0) -> _SoftSyncPlan:
        return _SoftSyncPlan()

    def plan_arrival(self, plan_state, arrival):
        plan_state.pending_stals.append(arrival.staleness)
        if len(plan_state.pending_stals) < self.c:
            return PlanVerdict(False)
        stal = float(np.mean(plan_state.pending_stals))
        n = len(plan_state.pending_stals)
        plan_state.pending_stals = []
        return PlanVerdict(True, stal, n)

    def init_scan_state(self, params_like):
        # the device window: a running gradient sum (grads share the
        # params dtype, matching the legacy pending-list summation)
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, p.dtype), params_like)

    def on_arrival_scan(self, aux, grads, row):
        acc = jax.tree_util.tree_map(lambda a, g: a + g, aux, grads)
        agg = jax.tree_util.tree_map(lambda a: a / self.c, acc)
        new_aux = jax.tree_util.tree_map(
            lambda a: jnp.where(row["apply"], jnp.zeros_like(a), a), acc)
        return new_aux, agg


def staleness_schedule(step: int, target: int, ramp_steps: int) -> int:
    """Paper trick: slowly increase staleness over the first epochs."""
    if target <= 0 or ramp_steps <= 0:
        return target
    return int(min(target, np.ceil(target * (step + 1) / ramp_steps)))


@dataclasses.dataclass
class _StalenessState:
    rng: np.random.RandomState
    buffer: List[Tuple[int, Any]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _StalenessPlan:
    """Host half of the old-gradient FIFO: (version tag, ring slot) pairs.

    Gradient values live on device in the ring buffer carried by the
    fused scan; the plan tracks which slot holds which entry. Slots are
    assigned round-robin (``writes % capacity``) — safe because the FIFO
    never holds more than ``scan_capacity`` live entries.
    """

    rng: np.random.RandomState
    fifo: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    writes: int = 0


@dataclasses.dataclass(frozen=True)
class Staleness(EventStrategy):
    """§2.1 controlled rig: serial SGD applying the gradient computed
    `tau` steps ago (old-gradient buffer), tau ramped over `ramp_steps`
    with optional +-jitter. tau=0 is bit-exact serial SGD (tested)."""

    tau: int
    ramp_steps: int = 0
    jitter: int = 0

    name = "staleness"
    uses_clock = False
    stals_per_arrival = False
    losses_per_arrival = True
    scan_supported = True

    @property
    def total_workers(self) -> int:
        return 1

    @property
    def scan_capacity(self) -> int:
        """Static ring-buffer size: the FIFO holds at most tau+jitter
        entries after an append (apply pops once len exceeds tau)."""
        return max(1, self.tau + self.jitter + 1)

    def init_state(self, seed: int = 0) -> _StalenessState:
        return _StalenessState(rng=np.random.RandomState(seed))

    def _effective_tau(self, rng: np.random.RandomState,
                       arrival: Arrival) -> int:
        """The ramped + jittered tau for this arrival. Shared by the
        legacy and plan paths: fused/legacy checkpoint compatibility
        depends on both consuming the SAME schedule and RNG draw order."""
        tau = staleness_schedule(arrival.index, self.tau, self.ramp_steps)
        if self.jitter > 0 and tau > 0:
            tau = max(0, tau + int(rng.randint(-self.jitter,
                                               self.jitter + 1)))
        return tau

    def on_arrival(self, state, grads, arrival):
        tau = self._effective_tau(state.rng, arrival)
        state.buffer.append((arrival.version, grads))
        # apply the OLDEST buffered gradient once it is `tau` steps old;
        # growing tau pauses updates while the buffer fills — mimicking the
        # worker ramp-up the paper uses for stability
        if len(state.buffer) <= tau:
            return None
        computed_at, g = state.buffer.pop(0)
        return ReadyUpdate(g, float(arrival.version - computed_at), 1)

    def init_plan_state(self, seed: int = 0) -> _StalenessPlan:
        return _StalenessPlan(rng=np.random.RandomState(seed))

    def plan_arrival(self, plan_state, arrival):
        tau = self._effective_tau(plan_state.rng, arrival)
        slot = plan_state.writes % self.scan_capacity
        plan_state.writes += 1
        plan_state.fifo.append((arrival.version, slot))
        assert len(plan_state.fifo) <= self.scan_capacity
        if len(plan_state.fifo) <= tau:
            return PlanVerdict(False, slot_w=slot)
        tag, read_slot = plan_state.fifo.pop(0)
        return PlanVerdict(True, float(arrival.version - tag), 1,
                           slot_w=slot, slot_r=read_slot)

    def init_scan_state(self, params_like):
        c = self.scan_capacity
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((c,) + p.shape, p.dtype), params_like)

    def on_arrival_scan(self, aux, grads, row):
        ring = jax.tree_util.tree_map(
            lambda r, g: r.at[row["slot_w"]].set(g), aux, grads)
        agg = jax.tree_util.tree_map(lambda r: r[row["slot_r"]], ring)
        return ring, agg


# ---------------------------------------------------------------------------
# The chunked event engine: host plan for the device scan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanVerdict:
    """``plan_arrival``'s gradient-free twin of ``on_arrival``'s verdict."""

    apply: bool
    staleness: float = 0.0
    selected: int = 0
    slot_w: int = 0          # staleness ring slot written by this arrival
    slot_r: int = 0          # ring slot holding the gradient applied


@dataclasses.dataclass
class EventPlan:
    """One chunk of K arrivals, host-precomputed into flat arrays.

    Everything the device scan cannot cheaply decide is resolved here —
    which arrivals apply a PS update, each update's lr-schedule step,
    the ring-buffer slots, and the full staleness bookkeeping. All of it
    is a pure function of the scheduler and per-arrival counters,
    independent of gradient values, which is what makes the fused path
    possible at all.
    """

    worker: np.ndarray             # [K] arrival worker ids
    draw: np.ndarray               # [K] per-worker batch draw index
    time: np.ndarray               # [K] arrival clock (simulated s)
    apply: np.ndarray              # [K] bool: a PS update applies here
    step: np.ndarray               # [K] PS version at arrival (update step)
    arrival_staleness: np.ndarray  # [K] staleness of each arrival
    update_staleness: np.ndarray   # [K] staleness of the applied update
    selected: np.ndarray           # [K] gradients aggregated per update
    slot_w: np.ndarray             # [K] ring write slot (staleness rig)
    slot_r: np.ndarray             # [K] ring read slot (staleness rig)
    updates: int                   # number of True entries in `apply`

    def __len__(self) -> int:
        return len(self.worker)

    def rows(self) -> Dict[str, jnp.ndarray]:
        """The per-arrival scan inputs, uploaded once per chunk."""
        return {"worker": jnp.asarray(self.worker, jnp.int32),
                "apply": jnp.asarray(self.apply),
                "step": jnp.asarray(self.step, jnp.int32),
                "slot_w": jnp.asarray(self.slot_w, jnp.int32),
                "slot_r": jnp.asarray(self.slot_r, jnp.int32)}


def plan_events(strategy: "EventStrategy", sched, plan_state: Any,
                read_version: np.ndarray, draws: np.ndarray, *,
                version0: int, arrival0: int, num_updates: int) -> EventPlan:
    """Pop arrivals from `sched` until `num_updates` PS updates are planned.

    The host twin of ``run_events``' control flow with the gradient math
    stripped out: identical pop/push RNG discipline and per-arrival
    bookkeeping, so the fused scan replays the exact update/staleness
    sequence. Mutates ``sched``, ``plan_state``, ``read_version`` and
    ``draws`` in place. The returned plan's LAST arrival always applies
    the final update, so chunk boundaries land exactly on PS-update
    counts (checkpoint/kill semantics unchanged) and windowed strategies
    (softsync) hold no pending gradients between chunks.
    """
    cols: Dict[str, list] = {k: [] for k in
                             ("worker", "draw", "time", "apply", "step",
                              "astal", "ustal", "sel", "sw", "sr")}
    version, arrival, updates = int(version0), int(arrival0), 0
    while updates < num_updates:
        t, wk = sched.pop()
        ar = Arrival(index=arrival, worker=wk, time=float(t),
                     staleness=int(version - read_version[wk]),
                     version=version)
        arrival += 1
        v = strategy.plan_arrival(plan_state, ar)
        cols["worker"].append(wk)
        cols["draw"].append(int(draws[wk]))
        draws[wk] += 1
        cols["time"].append(float(t))
        cols["apply"].append(bool(v.apply))
        cols["step"].append(version)
        cols["astal"].append(ar.staleness)
        cols["ustal"].append(float(v.staleness))
        cols["sel"].append(int(v.selected))
        cols["sw"].append(int(v.slot_w))
        cols["sr"].append(int(v.slot_r))
        if v.apply:
            version += 1
            updates += 1
        read_version[wk] = version
        sched.push(t, wk)
    return EventPlan(
        worker=np.asarray(cols["worker"], np.int32),
        draw=np.asarray(cols["draw"], np.int64),
        time=np.asarray(cols["time"], np.float64),
        apply=np.asarray(cols["apply"], bool),
        step=np.asarray(cols["step"], np.int32),
        arrival_staleness=np.asarray(cols["astal"], np.int64),
        update_staleness=np.asarray(cols["ustal"], np.float64),
        selected=np.asarray(cols["sel"], np.int64),
        slot_w=np.asarray(cols["sw"], np.int32),
        slot_r=np.asarray(cols["sr"], np.int32),
        updates=updates)


# ---------------------------------------------------------------------------
# The functional event engine (what the deprecated shims delegate to)
# ---------------------------------------------------------------------------


class VersionedReads:
    """Per-worker read-parameter copies, stored once per PS version.

    The legacy engine kept a ``read_params`` list with one slot per
    worker; the slots were references, but the list obscured the sharing
    and nothing enforced it. This store makes the invariant structural:
    every worker whose read version equals the current version shares ONE
    reference to the live params, and a distinct tree is retained only
    for versions some worker still holds (copy-on-divergence). Peak host
    memory is O(distinct live versions), not O(num_workers) — the
    difference between 100 retained parameter trees and a handful for
    ``num_workers=100`` async runs.
    """

    def __init__(self, params0: Any, num_workers: int):
        self.version = np.zeros(num_workers, dtype=np.int64)
        self._trees: Dict[int, Any] = {0: params0}
        self._readers: Dict[int, int] = {0: num_workers}

    def read(self, worker: int) -> Any:
        return self._trees[int(self.version[worker])]

    def write(self, worker: int, params: Any, version: int) -> None:
        old, new = int(self.version[worker]), int(version)
        if old == new:          # params cannot change without an update
            return
        self._readers[old] -= 1
        if not self._readers[old]:
            del self._trees[old], self._readers[old]
        self.version[worker] = new
        if new in self._readers:
            self._readers[new] += 1
        else:
            self._trees[new] = params
            self._readers[new] = 1

    @property
    def distinct_versions(self) -> int:
        return len(self._trees)


@dataclasses.dataclass
class AsyncResult:
    params: Any
    ema: Any
    losses: np.ndarray            # loss at each PS update (or arrival)
    staleness: np.ndarray         # staleness of each applied gradient
    sim_time: np.ndarray          # wall-clock (simulated s) of each update
    updates: int


def run_events(strategy: EventStrategy, grad_fn: Callable,
               update_fn: Callable, params0: Any,
               batch_fn: Callable[[int, int], Dict], num_updates: int,
               latency: Optional[LatencyModel] = None, seed: int = 0,
               ema_decay: float = 0.0,
               init_opt_state: Optional[Callable] = None) -> AsyncResult:
    """Drive an event strategy to `num_updates` parameter-server updates.

    grad_fn(params, batch) -> (loss, grads);
    update_fn(params, opt_state, grads, step) -> (params, opt_state, ...)
      (the caller closes over the optimizer; step drives the lr schedule;
      extra trailing return values — e.g. ``make_update_fn``'s stats dict
      — are ignored);
    batch_fn(worker, draw_index) -> batch.

    ``init_opt_state(params0) -> opt_state`` makes optimizer-state
    initialization explicit — one contract shared with the fused scan
    path, which cannot lazily initialize inside a traced body. When
    omitted it is read off ``update_fn.init_opt_state`` (set by
    ``make_update_fn``); with neither present the legacy handshake
    applies: ``opt_state`` starts as None and the caller's ``update_fn``
    closure initializes it on first use.

    Bit-exact port of the legacy ``async_sim.simulate_*`` loops: same
    RandomState draw order, same heap discipline, same read-after-update
    parameter-copy semantics (see :class:`VersionedReads` — workers at
    the current version share one reference, copies exist only per
    divergent version).
    """
    w = strategy.total_workers
    if strategy.uses_clock:
        sched = EventScheduler(w, latency or PaperCalibrated(), seed)
    else:
        sched = SerialScheduler()
    state = strategy.init_state(seed)
    params = params0
    if init_opt_state is None:
        init_opt_state = getattr(update_fn, "init_opt_state", None)
    opt_state = init_opt_state(params0) if init_opt_state else None
    ema_state = ema_lib.init(params) if ema_decay > 0 else None

    # worker state: one shared reference per distinct read version
    reads = VersionedReads(params, w)
    draws = np.zeros(w, dtype=np.int64)

    losses, stals, times = [], [], []
    version = 0
    arrival_index = 0
    while version < num_updates:
        t, wk = sched.pop()
        batch = batch_fn(wk, int(draws[wk]))
        draws[wk] += 1
        loss, grads = grad_fn(reads.read(wk), batch)
        arrival = Arrival(index=arrival_index, worker=wk, time=t,
                          staleness=int(version - reads.version[wk]),
                          version=version)
        arrival_index += 1
        if strategy.stals_per_arrival:
            stals.append(arrival.staleness)
        if strategy.losses_per_arrival:
            losses.append(float(loss))
        ready = strategy.on_arrival(state, grads, arrival)
        if ready is not None:
            out = update_fn(params, opt_state, ready.grads, version)
            params, opt_state = out[0], out[1]
            if ema_state is not None:
                ema_state = ema_lib.update(ema_state, params, ema_decay)
            if not strategy.stals_per_arrival:
                stals.append(int(ready.staleness))
            if not strategy.losses_per_arrival:
                losses.append(float(loss))
            times.append(t)
            version += 1
        # worker reads the fresh params and starts its next mini-batch
        reads.write(wk, params, version)
        sched.push(t, wk)

    sim_time = (np.arange(len(losses), dtype=np.float64)
                if strategy.losses_per_arrival else np.array(times))
    return AsyncResult(params=params,
                       ema=ema_lib.value(ema_state) if ema_state else params,
                       losses=np.array(losses), staleness=np.array(stals),
                       sim_time=sim_time, updates=version)


# ---------------------------------------------------------------------------
# Trainer-side builders (shared by the Trainer and the parity tests)
# ---------------------------------------------------------------------------


def make_grad_fn(model) -> Callable:
    """Jitted (params, batch) -> (loss, grads) for one worker's batch.

    LM models (``per_token_loss``) use the valid-token weighted mean plus
    aux losses; classifier models (``per_example_loss``) use the plain
    per-example mean. The same builder backs the Trainer's event mode and
    the bit-exactness tests against the legacy simulators.
    """
    if hasattr(model, "per_token_loss"):
        def loss_fn(params, batch):
            per_tok, aux = model.per_token_loss(params, batch)
            labels = batch["labels"]
            if per_tok.shape[1] != labels.shape[1]:   # vlm prefix positions
                pad = per_tok.shape[1] - labels.shape[1]
                labels = jnp.concatenate(
                    [jnp.full((labels.shape[0], pad), -1, labels.dtype),
                     labels], 1)
            valid = (labels >= 0).astype(jnp.float32)
            return (jnp.sum(per_tok * valid)
                    / jnp.maximum(jnp.sum(valid), 1.0)) + aux
    else:
        def loss_fn(params, batch):
            return model.per_example_loss(params, batch).mean()

    return jax.jit(jax.value_and_grad(loss_fn))


def make_update_fn(optimizer, clip_norm: float = 0.0) -> Callable:
    """Jitted (params, opt_state, grads, step) -> (params, opt_state, stats).

    No donation: event mode keeps per-worker parameter copies that may
    alias the live params buffer. The returned callable carries
    ``init_opt_state`` (the optimizer's init) so every event engine —
    ``run_events``, the Trainer, and the fused scan — shares one explicit
    optimizer-state initialization contract instead of the legacy
    ``opt_state = None`` lazy handshake.
    """
    from repro.optim import optimizers as opt_lib

    jitted = jax.jit(
        lambda params, opt_state, grads, step: optimizer.apply(
            params,
            opt_lib.clip_by_global_norm(grads, clip_norm)[0]
            if clip_norm > 0 else grads,
            opt_state, step))

    # plain-function wrapper: jit callables reject attribute assignment
    def update_fn(params, opt_state, grads, step):
        return jitted(params, opt_state, grads, step)

    update_fn.init_opt_state = optimizer.init
    return update_fn


# ---------------------------------------------------------------------------
# Deprecation plumbing (shared by the aggregation/async_sim shims)
# ---------------------------------------------------------------------------


_WARNED: set = set()


def warn_once(key: str, message: str) -> None:
    """Emit a DeprecationWarning exactly once per entry point per process."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
