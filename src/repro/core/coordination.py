"""One coordination API for every regime the paper compares.

The paper's whole argument is a comparison — Async-Opt (Alg. 1/2) vs
Sync-Opt vs Sync-Opt with backup workers (Alg. 3/4) — so every regime
lives behind a single ``CoordinationStrategy`` protocol with two families:

* **Mask strategies** (``kind == "mask"``): one SPMD step per iteration.
  The strategy turns one iteration's worker arrival times into
  ``(mask over W workers, iteration wall time)``; the mask is *data* to
  the jitted train step (dropped workers still compute — their cycles are
  the price of the insurance, exactly as in the paper, whose backup
  workers' gradients are discarded on arrival).

    - ``FullSync``            paper's plain Sync-Opt: wait for everyone.
    - ``BackupWorkers(N, b)`` paper Alg. 3/4: first N arrivals count.
    - ``Timeout(d)``          paper §6 future work: everything within d
                              of the first arrival counts (>=1 always).

  ``select`` is the host (numpy) rule; ``select_jax`` is its traceable
  counterpart used inside the fused chunked trainer's ``lax.scan`` body;
  ``select_batch`` is the vectorized [K, W] form (row-wise bit-identical
  to ``select`` — the chunked trainer's replay contract).

* **Event strategies** (``kind == "event"``): a discrete-event scheduler
  pops gradient *arrivals* one at a time (per the shared ``LatencyModel``)
  and the strategy decides, per arrival, whether a parameter-server
  update applies (``on_arrival``):

    - ``Async``        paper Alg. 1/2: every arrival applies immediately,
                       stale by however many updates landed since the
                       worker read its parameter copy.
    - ``SoftSync(c)``  Zhang et al. (2015b): average every c arrivals,
                       then apply (stale gradients allowed — contrast
                       with the paper's hard drop).
    - ``Staleness``    paper §2.1's controlled rig: serial SGD applying
                       the gradient from tau steps ago (old-gradient
                       buffer + the paper's ramp-up trick); tau=0 is
                       bit-exact serial SGD.

Strategies are constructed from ``AggregationConfig`` by the string-keyed
registry in :mod:`repro.core.registry` (``get_strategy(cfg)``) — the only
construction path the Trainer uses. ``repro.train.loop.Trainer`` executes
both families, so async/softsync get checkpoint/resume, EMA, failure
injection, and the unified per-update metrics schema
``(step, loss, sim_time, selected, staleness)`` for free; see docs/api.md.

The functional engine ``run_events`` is the faithful port of the legacy
``async_sim.simulate_*`` discrete-event loops (same RandomState draw
order, same heap discipline), so the deprecated shims delegate here and
stay bit-exact.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ema as ema_lib
from repro.core.straggler import LatencyModel, PaperCalibrated


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


class CoordinationStrategy:
    """Base of every coordination regime.

    ``kind`` selects the Trainer execution mode: ``"mask"`` runs one SPMD
    step per iteration with a worker mask; ``"event"`` runs the
    discrete-event parameter-server loop. ``total_workers`` is the number
    of machines launched (N + b for backup workers).
    """

    kind: str = ""
    name: str = ""
    total_workers: int


class MaskStrategy(CoordinationStrategy):
    """Synchronous regimes: arrival times -> (worker mask, step time)."""

    kind = "mask"

    def select(self, arrivals: np.ndarray) -> Tuple[np.ndarray, float]:
        """arrivals: [W] seconds -> (mask bool [W], iteration_time)."""
        raise NotImplementedError

    def select_jax(self, arrivals: jnp.ndarray):
        """Traceable select: [W] jnp seconds -> (bool [W], f32 scalar)."""
        raise NotImplementedError

    def select_batch(self, arrivals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized select: [K, W] -> (masks [K, W], times [K]).

        Row i is bitwise-identical to select(arrivals[i]) — the fused
        chunked trainer relies on this for replay-exact equivalence.
        Subclasses override with a vectorized rule; this fallback loops.
        """
        pairs = [self.select(a) for a in arrivals]
        return (np.stack([m for m, _ in pairs]),
                np.array([t for _, t in pairs], np.float64))

    def effective_n(self) -> int:
        raise NotImplementedError


# Back-compat alias: the pre-registry name for the mask base class.
Strategy = MaskStrategy


@dataclasses.dataclass(frozen=True)
class FullSync(MaskStrategy):
    num_workers: int

    name = "full_sync"

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        mask = np.ones_like(arrivals, dtype=bool)
        return mask, float(arrivals.max())

    def select_jax(self, arrivals):
        return jnp.ones(arrivals.shape, dtype=bool), jnp.max(arrivals)

    def select_batch(self, arrivals):
        return (np.ones_like(arrivals, dtype=bool),
                arrivals.max(axis=-1).astype(np.float64))

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class BackupWorkers(MaskStrategy):
    """Aggregate the first N of N+b arrivals (paper Alg. 3/4)."""

    num_workers: int          # N
    backups: int              # b

    name = "backup"

    @property
    def total_workers(self) -> int:
        return self.num_workers + self.backups

    def select(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, kind="stable")
        mask = np.zeros_like(arrivals, dtype=bool)
        mask[order[:n]] = True
        return mask, float(arrivals[order[n - 1]])

    def select_jax(self, arrivals):
        n = self.num_workers
        order = jnp.argsort(arrivals)        # stable, matching np "stable"
        mask = jnp.zeros(arrivals.shape, dtype=bool).at[order[:n]].set(True)
        return mask, arrivals[order[n - 1]]

    def select_batch(self, arrivals):
        n = self.num_workers
        order = np.argsort(arrivals, axis=-1, kind="stable")
        masks = np.zeros_like(arrivals, dtype=bool)
        np.put_along_axis(masks, order[:, :n], True, axis=-1)
        times = np.take_along_axis(arrivals, order[:, n - 1:n], axis=-1)[:, 0]
        return masks, times.astype(np.float64)

    def effective_n(self) -> int:
        return self.num_workers


@dataclasses.dataclass(frozen=True)
class Timeout(MaskStrategy):
    """Aggregate all gradients arriving within `deadline_s` of the first."""

    num_workers: int
    deadline_s: float

    name = "timeout"

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def select(self, arrivals):
        t0 = arrivals.min()
        cutoff = t0 + self.deadline_s
        mask = arrivals <= cutoff
        return mask, float(min(arrivals.max(), cutoff))

    def select_jax(self, arrivals):
        cutoff = jnp.min(arrivals) + self.deadline_s
        return arrivals <= cutoff, jnp.minimum(jnp.max(arrivals), cutoff)

    def select_batch(self, arrivals):
        cutoff = arrivals.min(axis=-1) + self.deadline_s
        masks = arrivals <= cutoff[:, None]
        times = np.minimum(arrivals.max(axis=-1), cutoff)
        return masks, times.astype(np.float64)

    def effective_n(self) -> int:
        return self.num_workers     # varies per step; N is the upper bound


# ---------------------------------------------------------------------------
# Event side: scheduler + strategies
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Arrival:
    """One gradient arrival popped from the event scheduler."""

    index: int          # arrival counter (0, 1, 2, ...)
    worker: int
    time: float         # simulated seconds (arrival index for serial rigs)
    staleness: int      # updates applied since this worker read its params
    version: int        # PS update count at arrival time


@dataclasses.dataclass
class ReadyUpdate:
    """on_arrival's verdict when a PS update should apply now."""

    grads: Any          # aggregated gradient tree to apply
    staleness: float    # staleness of this update (mean over contributors)
    selected: int       # gradients aggregated into this update


def encode_rng(rng: Optional[np.random.RandomState]) -> Optional[Dict]:
    """JSON-able snapshot of an MT19937 RandomState (checkpoint meta)."""
    if rng is None:
        return None
    key, pos, has_gauss, cached = rng.get_state()[1:]
    return {"key": [int(x) for x in key], "pos": int(pos),
            "has_gauss": int(has_gauss), "cached": float(cached)}


def decode_rng(rng: np.random.RandomState, d: Dict) -> None:
    rng.set_state(("MT19937", np.array(d["key"], np.uint32), int(d["pos"]),
                   int(d["has_gauss"]), float(d["cached"])))


class EventScheduler:
    """The legacy discrete-event queue, extracted and checkpointable.

    Faithful port of the ``async_sim.simulate_*`` RNG discipline: one
    ``latency.sample(rng, (W,))`` draw at construction, then one
    ``latency.sample(rng, (1,))`` draw per re-scheduled worker — so every
    caller (deprecated shims, ``run_events``, the Trainer's event mode)
    replays the identical arrival sequence for the same (latency, seed).
    """

    def __init__(self, num_workers: int, latency: LatencyModel, seed: int):
        self.latency = latency
        self.rng = np.random.RandomState(seed)
        first = self.latency.sample(self.rng, (num_workers,))
        self.queue: List[Tuple[float, int]] = [
            (float(first[w]), w) for w in range(num_workers)]
        heapq.heapify(self.queue)

    def pop(self) -> Tuple[float, int]:
        return heapq.heappop(self.queue)

    def push(self, t: float, worker: int) -> None:
        """Reschedule `worker`'s next arrival after its current one at `t`."""
        dt = float(self.latency.sample(self.rng, (1,))[0])
        heapq.heappush(self.queue, (t + dt, worker))

    def drop_worker(self, worker: int) -> None:
        """Failure injection: the worker's gradient never arrives again."""
        self.queue = [e for e in self.queue if e[1] != worker]
        heapq.heapify(self.queue)

    # -- checkpointable state -------------------------------------------------

    def state_dict(self) -> Dict:
        return {"queue": [[t, int(w)] for t, w in self.queue],
                "rng": encode_rng(self.rng)}

    def load_state_dict(self, d: Dict) -> None:
        self.queue = [(float(t), int(w)) for t, w in d["queue"]]
        heapq.heapify(self.queue)
        decode_rng(self.rng, d["rng"])


class SerialScheduler:
    """Degenerate clock for serial rigs (the §2.1 staleness experiment):
    one logical worker arriving at t = 0, 1, 2, ..."""

    def __init__(self):
        self.t = 0

    def pop(self) -> Tuple[float, int]:
        t = self.t
        self.t += 1
        return float(t), 0

    def push(self, t: float, worker: int) -> None:
        pass

    def drop_worker(self, worker: int) -> None:
        raise ValueError("serial rigs have a single logical worker; "
                         "failure injection does not apply")

    def state_dict(self) -> Dict:
        return {"t": int(self.t)}

    def load_state_dict(self, d: Dict) -> None:
        self.t = int(d["t"])


class EventStrategy(CoordinationStrategy):
    """Asynchronous regimes: a per-arrival apply-or-buffer policy.

    ``uses_clock``          — False for serial rigs (SerialScheduler).
    ``stals_per_arrival``   — legacy AsyncResult.staleness records one
                              entry per *arrival* (async/softsync) vs per
                              *update* (staleness rig).
    ``losses_per_arrival``  — likewise for AsyncResult.losses.
    """

    kind = "event"
    uses_clock = True
    stals_per_arrival = True
    losses_per_arrival = False

    def init_state(self, seed: int = 0) -> Any:
        """Fresh mutable per-run state (buffers, strategy-local RNG)."""
        return None

    def on_arrival(self, state: Any, grads: Any,
                   arrival: Arrival) -> Optional[ReadyUpdate]:
        """Decide what the arrival of `grads` does to the parameter server."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Async(EventStrategy):
    """Paper Alg. 1/2: every arrival applies immediately (staleness ~ N)."""

    num_workers: int

    name = "async"

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def on_arrival(self, state, grads, arrival):
        return ReadyUpdate(grads, float(arrival.staleness), 1)


@dataclasses.dataclass
class _SoftSyncState:
    pending: List[Any] = dataclasses.field(default_factory=list)
    pending_stals: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class SoftSync(EventStrategy):
    """Zhang et al. (2015b): average every c arrivals, then apply."""

    num_workers: int
    c: int = 1

    name = "softsync"

    @property
    def total_workers(self) -> int:
        return self.num_workers

    def init_state(self, seed: int = 0) -> _SoftSyncState:
        return _SoftSyncState()

    def on_arrival(self, state, grads, arrival):
        state.pending.append(grads)
        state.pending_stals.append(arrival.staleness)
        if len(state.pending) < self.c:
            return None
        mean_g = jax.tree_util.tree_map(
            lambda *gs: sum(gs[1:], gs[0]) / len(gs), *state.pending)
        stal = float(np.mean(state.pending_stals))
        n = len(state.pending)
        state.pending = []
        state.pending_stals = []
        return ReadyUpdate(mean_g, stal, n)


def staleness_schedule(step: int, target: int, ramp_steps: int) -> int:
    """Paper trick: slowly increase staleness over the first epochs."""
    if target <= 0 or ramp_steps <= 0:
        return target
    return int(min(target, np.ceil(target * (step + 1) / ramp_steps)))


@dataclasses.dataclass
class _StalenessState:
    rng: np.random.RandomState
    buffer: List[Tuple[int, Any]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class Staleness(EventStrategy):
    """§2.1 controlled rig: serial SGD applying the gradient computed
    `tau` steps ago (old-gradient buffer), tau ramped over `ramp_steps`
    with optional +-jitter. tau=0 is bit-exact serial SGD (tested)."""

    tau: int
    ramp_steps: int = 0
    jitter: int = 0

    name = "staleness"
    uses_clock = False
    stals_per_arrival = False
    losses_per_arrival = True

    @property
    def total_workers(self) -> int:
        return 1

    def init_state(self, seed: int = 0) -> _StalenessState:
        return _StalenessState(rng=np.random.RandomState(seed))

    def on_arrival(self, state, grads, arrival):
        tau = staleness_schedule(arrival.index, self.tau, self.ramp_steps)
        if self.jitter > 0 and tau > 0:
            tau = max(0, tau + int(state.rng.randint(-self.jitter,
                                                     self.jitter + 1)))
        state.buffer.append((arrival.version, grads))
        # apply the OLDEST buffered gradient once it is `tau` steps old;
        # growing tau pauses updates while the buffer fills — mimicking the
        # worker ramp-up the paper uses for stability
        if len(state.buffer) <= tau:
            return None
        computed_at, g = state.buffer.pop(0)
        return ReadyUpdate(g, float(arrival.version - computed_at), 1)


# ---------------------------------------------------------------------------
# The functional event engine (what the deprecated shims delegate to)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AsyncResult:
    params: Any
    ema: Any
    losses: np.ndarray            # loss at each PS update (or arrival)
    staleness: np.ndarray         # staleness of each applied gradient
    sim_time: np.ndarray          # wall-clock (simulated s) of each update
    updates: int


def run_events(strategy: EventStrategy, grad_fn: Callable,
               update_fn: Callable, params0: Any,
               batch_fn: Callable[[int, int], Dict], num_updates: int,
               latency: Optional[LatencyModel] = None, seed: int = 0,
               ema_decay: float = 0.0) -> AsyncResult:
    """Drive an event strategy to `num_updates` parameter-server updates.

    grad_fn(params, batch) -> (loss, grads);
    update_fn(params, opt_state, grads, step) -> (params, opt_state)
      (the caller closes over the optimizer; step drives the lr schedule);
    batch_fn(worker, draw_index) -> batch.

    Bit-exact port of the legacy ``async_sim.simulate_*`` loops: same
    RandomState draw order, same heap discipline, same read-after-update
    parameter-copy semantics.
    """
    w = strategy.total_workers
    if strategy.uses_clock:
        sched = EventScheduler(w, latency or PaperCalibrated(), seed)
    else:
        sched = SerialScheduler()
    state = strategy.init_state(seed)
    params = params0
    opt_state = None  # lazily initialized by caller's update_fn via closure
    ema_state = ema_lib.init(params) if ema_decay > 0 else None

    # worker state: the params version each worker last read
    read_params: List[Any] = [params for _ in range(w)]
    read_version = np.zeros(w, dtype=np.int64)
    draws = np.zeros(w, dtype=np.int64)

    losses, stals, times = [], [], []
    version = 0
    arrival_index = 0
    while version < num_updates:
        t, wk = sched.pop()
        batch = batch_fn(wk, int(draws[wk]))
        draws[wk] += 1
        loss, grads = grad_fn(read_params[wk], batch)
        arrival = Arrival(index=arrival_index, worker=wk, time=t,
                          staleness=int(version - read_version[wk]),
                          version=version)
        arrival_index += 1
        if strategy.stals_per_arrival:
            stals.append(arrival.staleness)
        if strategy.losses_per_arrival:
            losses.append(float(loss))
        ready = strategy.on_arrival(state, grads, arrival)
        if ready is not None:
            params, opt_state = update_fn(params, opt_state, ready.grads,
                                          version)
            if ema_state is not None:
                ema_state = ema_lib.update(ema_state, params, ema_decay)
            if not strategy.stals_per_arrival:
                stals.append(int(ready.staleness))
            if not strategy.losses_per_arrival:
                losses.append(float(loss))
            times.append(t)
            version += 1
        # worker reads the fresh params and starts its next mini-batch
        read_params[wk] = params
        read_version[wk] = version
        sched.push(t, wk)

    sim_time = (np.arange(len(losses), dtype=np.float64)
                if strategy.losses_per_arrival else np.array(times))
    return AsyncResult(params=params,
                       ema=ema_lib.value(ema_state) if ema_state else params,
                       losses=np.array(losses), staleness=np.array(stals),
                       sim_time=sim_time, updates=version)


# ---------------------------------------------------------------------------
# Trainer-side builders (shared by the Trainer and the parity tests)
# ---------------------------------------------------------------------------


def make_grad_fn(model) -> Callable:
    """Jitted (params, batch) -> (loss, grads) for one worker's batch.

    LM models (``per_token_loss``) use the valid-token weighted mean plus
    aux losses; classifier models (``per_example_loss``) use the plain
    per-example mean. The same builder backs the Trainer's event mode and
    the bit-exactness tests against the legacy simulators.
    """
    if hasattr(model, "per_token_loss"):
        def loss_fn(params, batch):
            per_tok, aux = model.per_token_loss(params, batch)
            labels = batch["labels"]
            if per_tok.shape[1] != labels.shape[1]:   # vlm prefix positions
                pad = per_tok.shape[1] - labels.shape[1]
                labels = jnp.concatenate(
                    [jnp.full((labels.shape[0], pad), -1, labels.dtype),
                     labels], 1)
            valid = (labels >= 0).astype(jnp.float32)
            return (jnp.sum(per_tok * valid)
                    / jnp.maximum(jnp.sum(valid), 1.0)) + aux
    else:
        def loss_fn(params, batch):
            return model.per_example_loss(params, batch).mean()

    return jax.jit(jax.value_and_grad(loss_fn))


def make_update_fn(optimizer, clip_norm: float = 0.0) -> Callable:
    """Jitted (params, opt_state, grads, step) -> (params, opt_state, stats).

    No donation: event mode keeps per-worker parameter copies that may
    alias the live params buffer.
    """
    from repro.optim import optimizers as opt_lib

    def update(params, opt_state, grads, step):
        if clip_norm > 0:
            grads, gnorm = opt_lib.clip_by_global_norm(grads, clip_norm)
        return optimizer.apply(params, grads, opt_state, step)

    return jax.jit(update)


# ---------------------------------------------------------------------------
# Deprecation plumbing (shared by the aggregation/async_sim shims)
# ---------------------------------------------------------------------------


_WARNED: set = set()


def warn_once(key: str, message: str) -> None:
    """Emit a DeprecationWarning exactly once per entry point per process."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
