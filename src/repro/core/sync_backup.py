"""Sync-SGD with backup workers, SPMD-native (paper Alg. 3/4 on a TPU mesh).

The key identity: with the global batch laid out as W contiguous worker
shards of B/W examples, the paper's update

    theta <- theta - (lr/N) * sum_{w in fastest-N} G_w,
    G_w = mean gradient over worker w's mini-batch

equals the gradient of the *mask-weighted* loss

    L = sum_b weight_b * loss_b,
    weight_b = mask[worker_of(b)] * W / (N * B_global)

so no custom collective is needed: each device weights its local examples,
and the usual data-parallel psum over ('pod','data') performs the paper's
"aggregate first N" exactly. Dropped (backup) workers still compute — by
design, as in the paper.

``aggregate_masked`` provides the explicit stacked-gradient formulation
(used by the simulator, tests, and the Pallas backup_reduce kernel); the
two are proven equal in tests/test_sync_backup.py.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def worker_of_example(global_batch: int, num_workers: int) -> np.ndarray:
    """Example -> worker index, contiguous shards (matches data pipeline)."""
    per = global_batch // num_workers
    return np.repeat(np.arange(num_workers), per)


def per_example_weights(mask: jnp.ndarray, global_batch: int,
                        n_aggregate: int) -> jnp.ndarray:
    """weight_b = mask[worker_of(b)] / (N * per_worker_batch).

    Then sum_b weight_b * loss_b = (1/N) * sum_w mask_w * mean_{b in w} loss_b.
    """
    w = mask.shape[0]
    per = global_batch // w
    rep = jnp.repeat(mask.astype(jnp.float32), per)
    return rep / (n_aggregate * per)


def weighted_loss(per_example_loss: jnp.ndarray, mask: jnp.ndarray,
                  n_aggregate: int) -> jnp.ndarray:
    """per_example_loss: [B] (already averaged over tokens) -> scalar.

    Gradient of this scalar == paper's Alg. 4 update direction.
    """
    wts = per_example_weights(mask, per_example_loss.shape[0], n_aggregate)
    return jnp.sum(per_example_loss * wts)


def aggregate_masked(grads_stacked: Any, mask: jnp.ndarray,
                     n_aggregate: int) -> Any:
    """Explicit form: grads_stacked is a pytree with leading axis W.

    Returns (1/N) * sum_w mask_w * g_w — Alg. 4 line 7.
    """
    m = mask.astype(jnp.float32)

    def agg(g):
        mm = m.reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.sum(g * mm, axis=0) / n_aggregate

    return jax.tree_util.tree_map(agg, grads_stacked)


def make_mask(arrival_rank: jnp.ndarray, n_aggregate: int) -> jnp.ndarray:
    """rank (0 = fastest) -> bool mask selecting the fastest N."""
    return arrival_rank < n_aggregate


def per_worker_grads(loss_fn, params, batch: Dict[str, jnp.ndarray],
                     num_workers: int):
    """Reference helper: stack per-worker mean gradients [W, ...].

    Used by tests and the async/staleness simulators — NOT the SPMD path
    (which uses weighted_loss). loss_fn(params, shard_batch) -> scalar mean.
    """
    def reshard(x):
        b = x.shape[0]
        return x.reshape((num_workers, b // num_workers) + x.shape[1:])

    sharded = jax.tree_util.tree_map(reshard, batch)

    def worker_grad(shard):
        return jax.grad(lambda p: loss_fn(p, shard))(params)

    return jax.lax.map(worker_grad, sharded)
