"""JAX-native worker-latency sampling — the device half of the straggler
simulator.

Each numpy ``LatencyModel`` in ``repro.core.straggler`` has a `jax.random`
counterpart here so the fused chunked trainer (``straggler_backend =
'device'``) can sample arrivals *inside* the ``lax.scan`` body with zero
host involvement. The samplers are distribution-equivalent to the numpy
models (tests/test_straggler_jax.py checks moments and quantiles), not
stream-equivalent: `jax.random` and `np.random.RandomState` draw different
sequences, so bit-exact replay against the host simulator uses the 'host'
backend instead.

Determinism contract: arrivals for step ``s`` are a pure function of
``(base_key, s)`` via ``jax.random.fold_in`` — checkpoint/resume replays
the device arrival sequence exactly, mirroring the host simulator's
``(seed, step)`` seeding.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.straggler import (DeterministicStragglers, LatencyModel,
                                  LogNormal, PaperCalibrated, Uniform)

SampleFn = Callable[[jax.Array, Tuple[int, ...]], jax.Array]


def sample_paper_calibrated(model: PaperCalibrated, key, shape):
    k_jit, k_tail, k_exp = jax.random.split(key, 3)
    t = model.base + model.jitter * jax.random.exponential(k_jit, shape)
    straggle = jax.random.uniform(k_tail, shape) < model.p_tail
    t = t + straggle * model.tail * jax.random.exponential(k_exp, shape)
    return jnp.minimum(t, model.cap)


def sample_lognormal(model: LogNormal, key, shape):
    return model.median * jnp.exp(model.sigma * jax.random.normal(key, shape))


def sample_uniform(model: Uniform, key, shape):
    return jax.random.uniform(key, shape, minval=model.lo, maxval=model.hi)


def sample_deterministic_stragglers(model: DeterministicStragglers, key, shape):
    t = model.base + model.jitter * jax.random.exponential(key, shape)
    mult = np.ones(shape[-1])
    for w in model.slow_workers:
        mult[w] = model.slowdown
    return t * jnp.asarray(mult)


_SAMPLERS = {
    PaperCalibrated: sample_paper_calibrated,
    LogNormal: sample_lognormal,
    Uniform: sample_uniform,
    DeterministicStragglers: sample_deterministic_stragglers,
}


def register_sampler(model_cls, fn) -> None:
    """Extension point: fn(model, key, shape) -> arrivals."""
    _SAMPLERS[model_cls] = fn


def sampler_for(model: LatencyModel) -> SampleFn:
    """Returns sample(key, shape) -> arrivals for the given numpy model."""
    for cls, fn in _SAMPLERS.items():
        if type(model) is cls:
            return lambda key, shape: fn(model, key, shape)
    raise NotImplementedError(
        f"no JAX sampler registered for {type(model).__name__}; "
        "use straggler_backend='host' or register_sampler()")


def step_arrivals(model: LatencyModel, base_key, step, workers: int,
                  dead=None) -> jax.Array:
    """Arrivals for one step: fold_in(base_key, step), dead workers -> inf."""
    arr = sampler_for(model)(jax.random.fold_in(base_key, step), (workers,))
    if dead is not None:
        arr = jnp.where(dead, jnp.inf, arr)
    return arr


def chunk_arrivals(sample_fn: SampleFn, key, steps, num_workers: int,
                   dead=None) -> jax.Array:
    """[K, W] arrivals for a whole fused chunk in one vectorized draw.

    vmaps ``sample_fn`` over per-step ``fold_in(key, step)`` keys — the
    same streams as per-step generation, so results are invariant to how
    a run is partitioned into chunks — and marks dead workers with +inf
    (they never arrive). Hoisting this out of the ``lax.scan`` body is
    what keeps the per-iteration cost at the bare train-step compute:
    threefry expands to hundreds of HLO ops per key.
    """
    arr = jax.vmap(
        lambda s: sample_fn(jax.random.fold_in(key, s), (num_workers,)))(steps)
    if dead is not None:
        arr = jnp.where(dead[None, :], jnp.inf, arr)
    return arr
