"""Deprecated shims — the event engines moved to repro.core.coordination.

``simulate_async`` (paper Alg. 1/2), ``simulate_softsync`` (Zhang et al.
2015b) and ``simulate_staleness`` (paper §2.1's old-gradient rig) keep
their exact legacy signatures and bit-exact numerics: they delegate to
:func:`repro.core.coordination.run_events`, which is the faithful port of
the original discrete-event loops (same RandomState draw order, same heap
discipline). Each entry point emits a ``DeprecationWarning`` once per
process. New code should construct strategies via
``repro.core.registry.get_strategy`` and run them through
``repro.train.loop.run_experiment`` (see docs/api.md).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from repro.core.coordination import (Async, AsyncResult,       # noqa: F401
                                     SoftSync, Staleness, run_events,
                                     staleness_schedule, warn_once)
from repro.core.straggler import LatencyModel


def simulate_async(grad_fn: Callable, update_fn: Callable, params0: Any,
                   batch_fn: Callable[[int, int], Dict], num_workers: int,
                   num_updates: int, latency: Optional[LatencyModel] = None,
                   seed: int = 0, ema_decay: float = 0.0) -> AsyncResult:
    """Exact Alg. 1/2 event simulation (legacy entry point)."""
    warn_once("async_sim.simulate_async",
              "repro.core.async_sim.simulate_async is deprecated; use "
              "repro.train.loop.run_experiment with strategy='async' or "
              "repro.core.coordination.run_events")
    return run_events(Async(num_workers), grad_fn, update_fn, params0,
                      batch_fn, num_updates=num_updates, latency=latency,
                      seed=seed, ema_decay=ema_decay)


def simulate_softsync(grad_fn: Callable, update_fn: Callable, params0: Any,
                      batch_fn: Callable[[int, int], Dict], num_workers: int,
                      c: int, num_updates: int,
                      latency: Optional[LatencyModel] = None,
                      seed: int = 0) -> AsyncResult:
    """SoftSync baseline (legacy entry point)."""
    warn_once("async_sim.simulate_softsync",
              "repro.core.async_sim.simulate_softsync is deprecated; use "
              "repro.train.loop.run_experiment with strategy='softsync' or "
              "repro.core.coordination.run_events")
    return run_events(SoftSync(num_workers, c), grad_fn, update_fn, params0,
                      batch_fn, num_updates=num_updates, latency=latency,
                      seed=seed)


def simulate_staleness(grad_fn: Callable, update_fn: Callable, params0: Any,
                       batch_fn: Callable[[int], Dict], num_updates: int,
                       staleness: int, ramp_steps: int = 0,
                       ema_decay: float = 0.0, jitter: int = 0,
                       seed: int = 0) -> AsyncResult:
    """Serial SGD with a tau-step-old gradient (legacy entry point)."""
    warn_once("async_sim.simulate_staleness",
              "repro.core.async_sim.simulate_staleness is deprecated; use "
              "repro.train.loop.run_experiment with strategy='staleness' or "
              "repro.core.coordination.run_events")
    return run_events(Staleness(staleness, ramp_steps, jitter), grad_fn,
                      update_fn, params0,
                      lambda worker, draw: batch_fn(draw),
                      num_updates=num_updates, seed=seed,
                      ema_decay=ema_decay)
