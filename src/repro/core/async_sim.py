"""Async-Opt (paper Alg. 1/2) discrete-event simulator + §2.1 staleness rig.

``simulate_async`` reproduces the parameter-server semantics exactly:
each worker holds the parameter copy it last read; when its gradient
"arrives" (per the latency model), the PS applies it immediately — the
gradient is stale by however many updates landed since the read. Staleness
per update is recorded (Table 1 / Fig. 2 territory).

``simulate_staleness`` is the paper's §2.1 controlled experiment: serial
SGD but each update uses the gradient from `tau` steps ago (old-gradient
buffer), with the paper's ramp-up trick (staleness grows over the first
epochs) — with tau=0 it is bit-exact serial SGD (tested).

``simulate_softsync`` is the related-work baseline (Zhang et al. 2015b):
batch c gradients per (stale) update.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.straggler import LatencyModel, PaperCalibrated


@dataclasses.dataclass
class AsyncResult:
    params: Any
    ema: Any
    losses: np.ndarray            # loss at each PS update
    staleness: np.ndarray         # staleness of each applied gradient
    sim_time: np.ndarray          # wall-clock (simulated s) of each update
    updates: int


def simulate_async(grad_fn: Callable, update_fn: Callable, params0: Any,
                   batch_fn: Callable[[int, int], Dict], num_workers: int,
                   num_updates: int, latency: Optional[LatencyModel] = None,
                   seed: int = 0, ema_decay: float = 0.0) -> AsyncResult:
    """Exact Alg. 1/2 event simulation.

    grad_fn(params, batch) -> (loss, grads);
    update_fn(params, opt_state, grads, step) -> (params, opt_state);
      (the caller closes over the optimizer; step drives the lr schedule)
    batch_fn(worker, draw_index) -> batch.
    """
    latency = latency or PaperCalibrated()
    rng = np.random.RandomState(seed)
    params = params0
    opt_state = None  # lazily initialized by caller's update_fn via closure
    from repro.core import ema as ema_lib
    ema_state = ema_lib.init(params) if ema_decay > 0 else None

    # worker state: the params version it read, and its read "update count"
    read_params: List[Any] = [params for _ in range(num_workers)]
    read_version = np.zeros(num_workers, dtype=np.int64)
    draws = np.zeros(num_workers, dtype=np.int64)

    # event queue: (finish_time, worker)
    first = latency.sample(rng, (num_workers,))
    q = [(float(first[w]), w) for w in range(num_workers)]
    heapq.heapify(q)

    losses, stals, times = [], [], []
    version = 0
    while version < num_updates:
        t, w = heapq.heappop(q)
        batch = batch_fn(w, int(draws[w]))
        draws[w] += 1
        loss, grads = grad_fn(read_params[w], batch)
        params, opt_state = update_fn(params, opt_state, grads, version)
        if ema_state is not None:
            ema_state = ema_lib.update(ema_state, params, ema_decay)
        stals.append(version - read_version[w])
        losses.append(float(loss))
        times.append(t)
        version += 1
        # worker reads the fresh params and starts its next mini-batch
        read_params[w] = params
        read_version[w] = version
        heapq.heappush(q, (t + float(latency.sample(rng, (1,))[0]), w))

    return AsyncResult(params=params,
                       ema=ema_lib.value(ema_state) if ema_state else params,
                       losses=np.array(losses), staleness=np.array(stals),
                       sim_time=np.array(times), updates=version)


# ---------------------------------------------------------------------------
# §2.1: controlled staleness via an old-gradient buffer
# ---------------------------------------------------------------------------


def staleness_schedule(step: int, target: int, ramp_steps: int) -> int:
    """Paper trick: slowly increase staleness over the first epochs."""
    if target <= 0 or ramp_steps <= 0:
        return target
    return int(min(target, np.ceil(target * (step + 1) / ramp_steps)))


def simulate_staleness(grad_fn: Callable, update_fn: Callable, params0: Any,
                       batch_fn: Callable[[int], Dict], num_updates: int,
                       staleness: int, ramp_steps: int = 0,
                       ema_decay: float = 0.0, jitter: int = 0,
                       seed: int = 0) -> AsyncResult:
    """Serial SGD applying the gradient computed `tau` steps ago.

    tau = staleness (+- jitter, >=0), ramped over `ramp_steps`. tau=0 is
    exactly serial SGD. grad_fn(params, batch) -> (loss, grads).
    """
    rng = np.random.RandomState(seed)
    from repro.core import ema as ema_lib
    params = params0
    opt_state = None
    ema_state = ema_lib.init(params) if ema_decay > 0 else None
    buffer: List[Tuple[int, Any]] = []   # (update_count at computation, grads)
    losses, stals = [], []
    applied = 0
    step = 0
    while applied < num_updates:
        tau = staleness_schedule(step, staleness, ramp_steps)
        if jitter > 0 and tau > 0:
            tau = max(0, tau + int(rng.randint(-jitter, jitter + 1)))
        batch = batch_fn(step)
        loss, grads = grad_fn(params, batch)
        buffer.append((applied, grads))
        losses.append(float(loss))
        # apply the OLDEST buffered gradient once it is `tau` steps old;
        # with tau == 0 this is exactly serial SGD (apply what we just
        # computed). Growing tau pauses updates while the buffer fills —
        # mimicking the worker ramp-up the paper uses for stability.
        if len(buffer) > tau:
            computed_at, g = buffer.pop(0)
            params, opt_state = update_fn(params, opt_state, g, applied)
            if ema_state is not None:
                ema_state = ema_lib.update(ema_state, params, ema_decay)
            stals.append(applied - computed_at)
            applied += 1
        step += 1

    return AsyncResult(params=params,
                       ema=ema_lib.value(ema_state) if ema_state else params,
                       losses=np.array(losses), staleness=np.array(stals),
                       sim_time=np.arange(len(losses), dtype=np.float64),
                       updates=applied)


def simulate_softsync(grad_fn: Callable, update_fn: Callable, params0: Any,
                      batch_fn: Callable[[int, int], Dict], num_workers: int,
                      c: int, num_updates: int,
                      latency: Optional[LatencyModel] = None,
                      seed: int = 0) -> AsyncResult:
    """SoftSync (Zhang et al. 2015b): average every c arrivals, then apply
    (stale gradients allowed — contrast with the paper's hard drop)."""
    latency = latency or PaperCalibrated()
    rng = np.random.RandomState(seed)
    params = params0
    opt_state = None
    read_params = [params for _ in range(num_workers)]
    read_version = np.zeros(num_workers, dtype=np.int64)
    draws = np.zeros(num_workers, dtype=np.int64)
    first = latency.sample(rng, (num_workers,))
    q = [(float(first[w]), w) for w in range(num_workers)]
    heapq.heapify(q)

    pend: List[Any] = []
    losses, stals, times = [], [], []
    version = 0
    while version < num_updates:
        t, w = heapq.heappop(q)
        batch = batch_fn(w, int(draws[w]))
        draws[w] += 1
        loss, grads = grad_fn(read_params[w], batch)
        pend.append(grads)
        stals.append(version - read_version[w])
        if len(pend) >= c:
            mean_g = jax.tree_util.tree_map(
                lambda *gs: sum(gs[1:], gs[0]) / len(gs), *pend)
            params, opt_state = update_fn(params, opt_state, mean_g, version)
            pend = []
            version += 1
            losses.append(float(loss))
            times.append(t)
        read_params[w] = params
        read_version[w] = version
        heapq.heappush(q, (t + float(latency.sample(rng, (1,))[0]), w))

    return AsyncResult(params=params, ema=params, losses=np.array(losses),
                       staleness=np.array(stals), sim_time=np.array(times),
                       updates=version)
