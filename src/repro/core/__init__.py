"""The paper's contribution: synchronous optimization with backup workers,
the async/staleness baselines, straggler models, and EMA evaluation."""
from repro.core import aggregation, async_sim, ema, events, straggler, sync_backup
from repro.core.aggregation import BackupWorkers, FullSync, Timeout
from repro.core.events import StepEvent, StragglerSimulator
from repro.core.straggler import (DeterministicStragglers, LogNormal,
                                  PaperCalibrated, Uniform)
