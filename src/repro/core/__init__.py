"""The paper's contribution: one coordination API over synchronous
optimization with backup workers, the async/softsync/staleness baselines,
straggler models, and EMA evaluation. Strategies are built from
``AggregationConfig`` via ``repro.core.registry.get_strategy``."""
from repro.core import (aggregation, async_sim, coordination, ema, events,
                        registry, straggler, sync_backup)
from repro.core.coordination import (Async, BackupWorkers,
                                     CoordinationStrategy, FullSync,
                                     SoftSync, Staleness, Timeout)
from repro.core.events import StepEvent, StragglerSimulator
from repro.core.registry import get_strategy
from repro.core.straggler import (DeterministicStragglers, LogNormal,
                                  PaperCalibrated, Uniform)
