"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: [B, H, S, D]; k/v: [B, KV, S, D] -> [B, H, S, D]. f32 math."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    diff = pos[:, None] - pos[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (diff >= 0)
    if window > 0:
        mask = mask & (diff < window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def reference_wkv6(r, k, v, w, u, state=None):
    """Sequential RWKV-6 recurrence. r/k/v/w: [B, H, S, D]; u: [H, D].

    S_t = diag(w_t) S_{t-1} + k_t v_t^T;  o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    Returns (out [B,H,S,D], final_state [B,H,D,D]).
    """
    b, h, s, d = r.shape
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    if state is None:
        state = jnp.zeros((b, h, d, d), f32)

    def step(st, inp):
        rt, kt, vt, wt = inp                                   # [B,H,D]
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", rt, st + u[None, :, :, None] * kv)
        return wt[..., :, None] * st + kv, out

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (r, k, v, w))  # [S,B,H,D]
    state, outs = jax.lax.scan(step, state, xs)
    return outs.transpose(1, 2, 0, 3), state


def reference_backup_reduce(grads, mask, n_aggregate: int):
    """grads: [W, N]; mask: [W] -> [N] = (1/N_agg) sum_w mask_w grads_w."""
    m = mask.astype(jnp.float32)
    return (m @ grads.astype(jnp.float32)) / n_aggregate
