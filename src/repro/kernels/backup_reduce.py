"""Pallas TPU kernel: masked backup-worker gradient reduction.

The on-chip half of the paper's Alg. 4 line 7: given W stacked worker
gradients (one shard each, flattened) and the [W] selection mask, produce
(1/N) * sum_{selected} g_w as a single fused pass — a [W] x [W, BN] matvec
per grid block, with the gradient tile streamed through VMEM once (the op
is bandwidth-bound; fusing mask+scale+reduce avoids a second HBM pass over
the W-times-larger stacked buffer).

Grid: 1-D over flattened-parameter blocks. Mask lives in a [W] VMEM block
replicated to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(g_ref, m_ref, o_ref, *, inv_n: float):
    g = g_ref[...].astype(jnp.float32)              # [W, BN]
    m = m_ref[...].astype(jnp.float32)              # [W]
    o_ref[...] = (jnp.dot(m, g, preferred_element_type=jnp.float32)
                  * inv_n).astype(o_ref.dtype)


def backup_reduce(grads: jnp.ndarray, mask: jnp.ndarray, n_aggregate: int, *,
                  block: int = 4096, interpret: bool = False) -> jnp.ndarray:
    """grads: [W, N] stacked worker grads; mask: [W] -> [N] masked mean.

    N may be any size: the flattened gradient is zero-padded up to the
    block multiple for the grid and the padding is sliced off the output
    (zeros reduce to zeros, so the padded lanes are inert).
    """
    w, n = grads.shape
    block = min(block, n)
    pad = (-n) % block
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    padded = n + pad
    kernel = functools.partial(_reduce_kernel, inv_n=1.0 / n_aggregate)
    out = pl.pallas_call(
        kernel,
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec((w, block), lambda i: (0, i)),
            pl.BlockSpec((w,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(grads, mask.astype(jnp.float32))
    return out[:n] if pad else out
