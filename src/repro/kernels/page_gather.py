"""Page-gather kernel for paged decode attention.

Decode attention over a paged KV cache needs, per slot, the slot's pages
assembled into a contiguous ``[tokens, kv_heads, head_dim]`` view. The
reference path is a jnp advanced-index gather (XLA lowers it to a dynamic
gather from HBM); the Pallas kernel instead drives one DMA per (slot,
logical page) grid cell, using the page table as a **scalar-prefetch**
operand so the block index map can look up the physical page id before the
body runs (``pltpu.PrefetchScalarGridSpec`` — see the quantization-kernel
pattern in the Pallas guide). Dequantization of int8 pages fuses into the
same pass: payload and scale blocks are gathered together and multiplied
in VMEM, so the fp16 scales never round-trip through a separate gather.

Like the other kernels in this package the Pallas path runs natively on
TPU and under ``interpret=True`` elsewhere, and is parity-tested against
the jnp twin (tests/test_serve_engine.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

try:                                       # TPU-specific grid spec
    from jax.experimental.pallas import tpu as pltpu
except ImportError:                        # pragma: no cover
    pltpu = None


def gather_pages_reference(pool: jnp.ndarray, page_table: jnp.ndarray,
                           scales: Optional[jnp.ndarray] = None,
                           out_dtype=jnp.float32) -> jnp.ndarray:
    """jnp twin: pool [P, ps, kv, hd], page_table [B, maxp] ->
    [B, maxp*ps, kv, hd] (dead table entries gather the trash page)."""
    b, maxp = page_table.shape
    _, ps, kv, hd = pool.shape
    g = pool[page_table]                            # [B, maxp, ps, kv, hd]
    if scales is not None:
        s = scales[page_table]                      # [B, maxp, ps, kv]
        g = g.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    return g.reshape(b, maxp * ps, kv, hd).astype(out_dtype)


def _gather_kernel(tbl_ref, pool_ref, out_ref):
    out_ref[0, 0] = pool_ref[0].astype(out_ref.dtype)


def _gather_dequant_kernel(tbl_ref, pool_ref, scale_ref, out_ref):
    deq = (pool_ref[0].astype(jnp.float32)
           * scale_ref[0].astype(jnp.float32)[..., None])
    out_ref[0, 0] = deq.astype(out_ref.dtype)


def gather_pages_pallas(pool: jnp.ndarray, page_table: jnp.ndarray,
                        scales: Optional[jnp.ndarray] = None,
                        out_dtype=jnp.float32,
                        interpret: bool = True) -> jnp.ndarray:
    """Pallas page gather (+ fused int8 dequant when ``scales`` is given)."""
    if pltpu is None:                      # pragma: no cover
        return gather_pages_reference(pool, page_table, scales, out_dtype)
    b, maxp = page_table.shape
    _, ps, kv, hd = pool.shape

    in_specs = [pl.BlockSpec((1, ps, kv, hd),
                             lambda i, p, tbl: (tbl[i, p], 0, 0, 0))]
    operands = [pool]
    kernel = _gather_kernel
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, ps, kv),
                                     lambda i, p, tbl: (tbl[i, p], 0, 0)))
        operands.append(scales)
        kernel = _gather_dequant_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, maxp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, ps, kv, hd),
                               lambda i, p, tbl: (i, p, 0, 0, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, maxp, ps, kv, hd), out_dtype),
        interpret=interpret,
    )(page_table, *operands)
    return out.reshape(b, maxp * ps, kv, hd)


def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray,
                 scales: Optional[jnp.ndarray] = None, *,
                 out_dtype=jnp.float32, use_kernel: bool = False,
                 interpret: bool = True) -> jnp.ndarray:
    if use_kernel:
        return gather_pages_pallas(pool, page_table, scales, out_dtype,
                                   interpret=interpret)
    return gather_pages_reference(pool, page_table, scales, out_dtype)
