"""Pallas TPU kernels (validated in interpret mode on CPU):
flash_attention (causal/window/GQA/softcap), rwkv6 chunked wkv,
backup_reduce (masked worker-gradient reduction). See ops.py for the
jitted wrappers and ref.py for the jnp oracles."""
from repro.kernels import ops, ref
