"""Pallas TPU kernel: chunked RWKV-6 wkv recurrence (data-dependent decay).

Grid (B, H, n_chunks); the chunk axis is minormost, so the [D, D] head
state carries across chunk iterations in VMEM scratch. Each step loads
(r, k, v, w) chunk tiles [C, D], computes the intra-chunk lower-triangular
attention form plus the carried-state term, and updates the state:

    A      = cumsum(log w)                  (inclusive, per channel)
    scores = (r * exp(A_excl)) @ (k * exp(-A))^T   (strictly lower tri)
    out    = scores @ v + (r u k) * v + (r * exp(A_excl)) @ S
    S      = diag(exp(A_C)) S + (k * exp(A_C - A))^T @ v

Chunk of 16 with |log w| clamped <= 5 upstream keeps exp(-A) finite in f32
(see repro.models.rwkv6). This is the TPU adaptation of the RWKV CUDA
kernel: a serial per-token loop becomes MXU-shaped [C,D]x[D,C] matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
                chunk: int, d: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)                 # [C, D]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                    # [D]

    logw = jnp.log(jnp.maximum(w, 1e-30))
    acc = jnp.cumsum(logw, axis=0)                      # inclusive [C, D]
    acc_ex = acc - logw                                 # exclusive

    ri = r * jnp.exp(acc_ex)                            # decay-weighted read
    kj = k * jnp.exp(-acc)
    scores = jnp.dot(ri, kj.T, preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ti > tj, scores, 0.0)            # strictly lower tri

    bonus = jnp.sum(r * u[None, :] * k, axis=1)         # diagonal (t == j)
    st = state_ref[...]                                 # [D, D]
    out = (jnp.dot(scores, v, preferred_element_type=jnp.float32)
           + bonus[:, None] * v
           + jnp.dot(ri, st, preferred_element_type=jnp.float32))
    o_ref[0, 0] = out.astype(o_ref.dtype)

    a_all = jnp.exp(acc[-1, :])                         # [D]
    k_dec = k * jnp.exp(acc[-1:, :] - acc)              # decay-to-chunk-end
    state_ref[...] = (a_all[:, None] * st
                      + jnp.dot(k_dec.T, v, preferred_element_type=jnp.float32))


def wkv6_chunked(r, k, v, w, u, *, chunk: int = 16,
                 interpret: bool = False) -> jnp.ndarray:
    """r/k/v/w: [B, H, S, D]; u: [H, D] -> out [B, H, S, D] (zero init state)."""
    b, h, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, d=d)
    spec = pl.BlockSpec((1, 1, chunk, d), lambda ib, ih, ic: (ib, ih, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, d), lambda ib, ih, ic: (ih, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
