"""Pallas TPU flash attention: causal + sliding-window + GQA + softcap.

TPU-native blocking: the grid is (batch, q_head, q_blocks, kv_blocks); the
kv axis is the minormost grid dimension, which Pallas TPU iterates
sequentially per (b, h, iq) — the online-softmax accumulators (m, l, acc)
live in VMEM scratch and carry across kv iterations. Block shapes are
(BQ, D) / (BK, D) tiles resident in VMEM, MXU-aligned (multiples of 128
in the contracted dims when D allows).

Layouts (arranged by ops.py): q [B, H, S, D]; k/v [B, KV, S, D]; the GQA
group mapping (kv_head = q_head // q_per_kv) happens in the k/v index_map —
no repeated-KV materialization in HBM.

Numerical scheme: f32 accumulation, running max-shifted exponentials —
identical algebra to ref.reference_attention (tested allclose over shape
and dtype sweeps in interpret mode).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, softcap: float, num_kv_blocks: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # [BQ, D]
    k = k_ref[0, 0].astype(jnp.float32)                     # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    diff = qpos - kpos
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (diff >= 0)
    if window > 0:
        mask = mask & (diff < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, S, D]; k/v: [B, KV, S, D] -> [B, H, S, D]."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    q_per_kv = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // q_per_kv, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // q_per_kv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
