"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real
TPU backends — controlled by REPRO_PALLAS_INTERPRET or the platform.
These wrappers also adapt layouts: models carry activations as
[B, S, H, D]; the kernels want [B, H, S, D].
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import backup_reduce as _br
from repro.kernels import flash_attention as _fa
from repro.kernels import rwkv6_scan as _wk


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention_bshd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         block_q=128, block_k=128):
    """q: [B, S, H, D]; k/v: [B, S, KV, D] -> [B, S, H, D]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, block_q=block_q, block_k=block_k,
                              interpret=_interpret_default())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, *, chunk=16):
    """r/k/v/w: [B, S, H, D]; u: [H, D] -> [B, S, H, D]."""
    args = [t.transpose(0, 2, 1, 3) for t in (r, k, v, w)]
    out = _wk.wkv6_chunked(*args, u, chunk=chunk,
                           interpret=_interpret_default())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("n_aggregate", "block"))
def backup_reduce(grads, mask, n_aggregate, *, block=4096):
    """grads: [W, N]; mask: [W] -> [N] = (1/N_agg) * sum_selected."""
    return _br.backup_reduce(grads, mask, n_aggregate, block=block,
                             interpret=_interpret_default())
