"""Fused bucketed reduce-then-psum: the collective half of Alg. 4 line 7.

``backup_reduce`` (the in-shard Pallas masked reduce) and the ``psum``
over the mesh ``'data'`` axis used to be two sequential steps on the
whole flattened gradient: nothing crossed the wire until the entire
[W_local, P] stack had been reduced, and the optimizer waited until the
entire [P] psum finished. This module fuses them into a *bucketed*
pipeline: the flat gradient is cut into fixed-size buckets and each
bucket's psum is issued the moment that bucket's in-shard reduce
completes — so with async collectives (the latency-hiding XLA recipe in
``launch.mesh.set_platform``) bucket i's wire time overlaps bucket
i+1's reduce compute. The unrolled per-bucket chain is exactly the
dependency structure XLA's latency-hiding scheduler needs; a single
monolithic reduce+psum gives it nothing to overlap.

Two in-shard reduce implementations, selected by ``use_kernel``:

* the ``kernels.backup_reduce`` Pallas kernel per bucket (one fused
  mask+scale+reduce pass over VMEM-streamed tiles; interpret mode
  off-TPU), or
* a jnp reference (``[W] @ [W, bucket]`` dot) — the oracle the property
  tests in ``tests/test_bucketed_reduce.py`` hold the kernel to.

The scalar *tail*: per-step monitoring scalars (the masked loss sum and
the aux-loss sum) ride in the last bucket's padding lanes, so the whole
step needs exactly ``ceil(P / bucket)`` collectives — with the default
single bucket, ONE psum per step where the unfused engine issued three
(gradient + two scalar reductions). On a CPU host with forced devices
every psum is a full cross-device thread rendezvous, so collective
count is the first-order cost this module removes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.backup_reduce import backup_reduce


def ref_masked_mean(grads: jnp.ndarray, mask: jnp.ndarray,
                    n_aggregate: int) -> jnp.ndarray:
    """The dense jnp oracle: [W, P] stacked grads, [W] mask ->
    (1/n_aggregate) * sum_w mask_w * g_w, in f32."""
    m = mask.astype(jnp.float32)
    return (m @ grads.astype(jnp.float32)) / n_aggregate


def bucket_bounds(total: int, bucket: int) -> Tuple[Tuple[int, int], ...]:
    """(lo, hi) slices cutting ``total`` lanes into ``bucket``-size pieces.

    ``bucket <= 0`` means one bucket spanning everything (the unbucketed
    fused path). The last bucket is ragged when ``bucket`` does not
    divide ``total``.
    """
    if total < 0:
        raise ValueError(f"total lanes must be >= 0 (got {total})")
    if bucket <= 0 or bucket >= total:
        return ((0, total),)
    return tuple((lo, min(lo + bucket, total))
                 for lo in range(0, total, bucket))


def reduce_then_psum(grads: jnp.ndarray, mask: jnp.ndarray,
                     n_aggregate: int, *,
                     axis_name: Optional[str] = None,
                     bucket: int = 0,
                     tail: Optional[jnp.ndarray] = None,
                     use_kernel: bool = True,
                     interpret: bool = False,
                     block: int = 4096
                     ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Bucketed masked reduce of [W, P] stacked grads, psum'd per bucket.

    Returns ``([P] f32 aggregated gradient, tail_out)`` where the
    gradient is ``(1/n_aggregate) * sum_{selected} g_w`` summed over
    ``axis_name`` (no collective when ``axis_name`` is None — the pure
    single-shard function the property tests exercise), and ``tail_out``
    is the [E] ``tail`` vector summed over ``axis_name`` (it rides the
    last bucket's psum; None in == None out).

    ``bucket`` is the lane count per collective (0 = single bucket);
    ``use_kernel`` picks the Pallas in-shard reduce vs the jnp dot;
    ``block`` is the Pallas grid tile within each bucket.
    """
    w, p = grads.shape
    if mask.shape != (w,):
        raise ValueError(f"mask shape {mask.shape} does not match the "
                         f"worker axis of grads {grads.shape}")
    mf = mask.astype(jnp.float32)

    def reduce_bucket(chunk: jnp.ndarray) -> jnp.ndarray:
        if w == 1:
            # one local worker: the masked mean is a scalar rescale of
            # the single row — no kernel / dot needed (the common case
            # when the mesh 'data' axis equals the worker count)
            return chunk[0].astype(jnp.float32) * (mf[0] / n_aggregate)
        if use_kernel and chunk.shape[1] > 0:
            return backup_reduce(chunk, mf, n_aggregate,
                                 block=block, interpret=interpret)
        return (mf @ chunk.astype(jnp.float32)) / n_aggregate

    bounds = bucket_bounds(p, bucket)
    out = []
    tail_out = None
    for i, (lo, hi) in enumerate(bounds):
        red = reduce_bucket(grads[:, lo:hi])
        last = i == len(bounds) - 1
        if last and tail is not None:
            # the monitoring scalars ride the final bucket's collective
            red = jnp.concatenate([red, tail.astype(jnp.float32)])
        if axis_name is not None:
            red = jax.lax.psum(red, axis_name)
        if last and tail is not None:
            red, tail_out = red[:hi - lo], red[hi - lo:]
        out.append(red)
    agg = out[0] if len(out) == 1 else jnp.concatenate(out)
    return agg, tail_out
