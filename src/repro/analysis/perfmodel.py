"""Analytic FLOP / HBM-byte accounting per (arch x shape) cell.

Why analytic: XLA's cost_analysis() counts while-loop bodies ONCE
(verified in tests/test_dryrun_analysis.py), so any scanned-layer model is
undercounted by ~num_layers. We control every model's op inventory, so we
account exactly — and validate against cost_analysis on small UNROLLED
configs (tests assert agreement on matmul-dominated models).

Conventions:
  * flops are global (all chips) per step; matmul = 2*M*N*K
  * causal attention scores use the exact average effective KV length
  * train multiplier: fwd + 2x bwd (+1x fwd recompute when remat='full')
  * HBM bytes are global per step; parameter traffic counts every
    data-parallel replica's shard reads (chips/model_shard copies)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models import registry


def _avg_kv(seq: int, window: int) -> float:
    """Mean number of attended KV positions per query (causal)."""
    if window <= 0 or window >= seq:
        return (seq + 1) / 2.0
    head = window * (window + 1) / 2.0          # positions < window
    rest = (seq - window) * window
    return (head + rest) / seq


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellFlops:
    fwd_layers: float          # per-step fwd flops inside the layer stack
    fwd_other: float           # logits / CE
    train: float               # full train-step flops (incl. remat policy)
    fwd: float                 # fwd-only (prefill; last-position logits)
    decode: float              # one decode step
    model_flops_train: float   # 6*N_active*D — the "useful flops" yardstick
    model_flops_fwd: float


def _attn_flops_per_tok(cfg, s_kv: float) -> float:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    d = cfg.d_model
    if cfg.attention_kind == "mla":
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        proj = (2 * d * (h * qk)
                + 2 * d * (m.kv_lora_rank + m.qk_rope_dim)
                + 2 * m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                + 2 * h * m.v_head_dim * d)
        attn = 2 * s_kv * h * qk + 2 * s_kv * h * m.v_head_dim
        return proj + attn
    proj = 2 * d * (h * hd) + 2 * 2 * d * (kv * hd) + 2 * (h * hd) * d
    attn = 2 * s_kv * h * hd * 2               # QK^T and PV
    return proj + attn


def _mlp_flops_per_tok(d: int, f: int, act: str) -> float:
    mults = 3 if act == "swiglu" else 2
    return 2.0 * d * f * mults


def _ssd_flops_per_tok(cfg) -> float:
    d = cfg.d_model
    h, hd, n = cfg.num_heads, cfg.resolved_head_dim, cfg.ssm.state_dim
    proj = 2 * d * (h * hd) + 2 * 2 * d * (h * n) + 2 * d * h
    scan = 6.0 * h * n * hd                    # decay+outer+read on [N,P] state
    out = 2 * (h * hd) * d
    return proj + scan + out


def _rwkv_flops_per_tok(cfg) -> float:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    heads = d // hd
    time_mix = 5 * 2 * d * d + 2 * 2 * 64 * d      # wr/wk/wv/wg/wo + decay lora
    wkv = 6.0 * heads * hd * hd                    # state decay+outer+read
    channel = 2 * d * cfg.d_ff * 2 + 2 * d * d     # wk, wv, wr
    return time_mix + wkv + channel


def _layer_flops_per_tok(cfg, layer_idx: int, s_kv_full: float,
                         s_kv_win: float) -> float:
    d = cfg.d_model
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        is_win = cfg.sliding_window > 0 and not (
            cfg.global_every > 0 and (layer_idx + 1) % cfg.global_every == 0)
        total = _attn_flops_per_tok(cfg, s_kv_win if is_win else s_kv_full)
        if cfg.moe.enabled and layer_idx >= cfg.moe.first_dense:
            m = cfg.moe
            total += 2 * d * m.num_experts
            total += m.top_k * _mlp_flops_per_tok(d, m.expert_d_ff, "swiglu")
            if m.num_shared_experts:
                total += _mlp_flops_per_tok(d, m.shared_d_ff, "swiglu")
        else:
            f = cfg.moe.dense_d_ff if (cfg.moe.enabled and cfg.moe.dense_d_ff) \
                else cfg.d_ff
            total += _mlp_flops_per_tok(d, f, cfg.hidden_act)
        return total
    if fam == "hybrid":
        return (_attn_flops_per_tok(cfg, s_kv_win) + _ssd_flops_per_tok(cfg)
                + _mlp_flops_per_tok(d, cfg.d_ff, cfg.hidden_act))
    if fam == "ssm":
        return _rwkv_flops_per_tok(cfg)
    raise ValueError(fam)


def cell_flops(cfg, shape, remat: str = "full") -> CellFlops:
    s, b = shape.seq_len, shape.global_batch
    t = b * s
    v = cfg.padded_vocab
    d = cfg.d_model
    s_full = _avg_kv(s, 0)
    s_win = _avg_kv(s, cfg.sliding_window)

    if cfg.family == "audio":
        t_enc = b * cfg.encoder_seq_len
        per_enc = (_attn_flops_per_tok(cfg, cfg.encoder_seq_len)     # bidir
                   + _mlp_flops_per_tok(d, cfg.d_ff, cfg.hidden_act))
        per_dec = (_attn_flops_per_tok(cfg, s_full)
                   + _attn_flops_per_tok(cfg, cfg.encoder_seq_len)   # cross
                   + _mlp_flops_per_tok(d, cfg.d_ff, cfg.hidden_act))
        fwd_layers = (t_enc * per_enc * cfg.num_encoder_layers
                      + t * per_dec * cfg.num_layers)
    else:
        fwd_layers = sum(t * _layer_flops_per_tok(cfg, i, s_full, s_win)
                         for i in range(cfg.num_layers))

    fwd_other = 2.0 * t * d * v                # training logits
    remat_extra = 1.0 if remat == "full" else 0.0
    train = (3.0 + remat_extra) * fwd_layers + 3.0 * fwd_other
    fwd = fwd_layers + 2.0 * b * d * v

    if cfg.family == "audio":
        per_dec = (_attn_flops_per_tok(cfg, float(s))
                   + _attn_flops_per_tok(cfg, cfg.encoder_seq_len)
                   + _mlp_flops_per_tok(d, cfg.d_ff, cfg.hidden_act))
        decode = b * per_dec * cfg.num_layers + 2.0 * b * d * v
    else:
        skv_full = float(s)
        skv_win = float(min(s, cfg.sliding_window)) if cfg.sliding_window > 0 \
            else float(s)
        decode = sum(b * _layer_flops_per_tok(cfg, i, skv_full, skv_win)
                     for i in range(cfg.num_layers)) + 2.0 * b * d * v

    n_active = registry.param_count(cfg, active_only=True)
    return CellFlops(fwd_layers=fwd_layers, fwd_other=fwd_other, train=train,
                     fwd=fwd, decode=decode,
                     model_flops_train=6.0 * n_active * t,
                     model_flops_fwd=2.0 * n_active * t)


# ---------------------------------------------------------------------------
# HBM traffic
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellBytes:
    train: float
    fwd: float
    decode: float
    cache_bytes: float          # resident KV/state cache (decode shapes)


def _cache_total_bytes(cfg, shape, dtype_bytes: int = 2) -> float:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        hd = cfg.rwkv_head_dim
        heads = cfg.d_model // hd
        return cfg.num_layers * b * (heads * hd * hd * 4 + 2 * cfg.d_model * 4)
    if cfg.family == "hybrid":
        w = min(s, cfg.sliding_window) if cfg.sliding_window > 0 else s
        attn = cfg.num_layers * b * w * 2 * cfg.num_kv_heads \
            * cfg.resolved_head_dim * dtype_bytes
        ssd = cfg.num_layers * b * cfg.num_heads * cfg.ssm.state_dim \
            * cfg.resolved_head_dim * 4
        return attn + ssd
    if cfg.attention_kind == "mla":
        m = cfg.mla
        return cfg.num_layers * b * s * (m.kv_lora_rank + m.qk_rope_dim) \
            * dtype_bytes
    per_layer_s = []
    for i in range(cfg.num_layers):
        is_win = cfg.sliding_window > 0 and not (
            cfg.global_every > 0 and (i + 1) % cfg.global_every == 0)
        per_layer_s.append(min(s, cfg.sliding_window) if is_win else s)
    kvb = sum(per_layer_s) * b * 2 * cfg.num_kv_heads \
        * cfg.resolved_head_dim * dtype_bytes
    if cfg.family == "audio":
        kvb += cfg.num_layers * b * cfg.encoder_seq_len * 2 * cfg.num_heads \
            * cfg.resolved_head_dim * dtype_bytes      # cross K/V
    return kvb


def cell_bytes(cfg, shape, *, chips: int, model_shard: int,
               param_bytes: int = 2, opt_slots: int = 2,
               zero1: bool = True, remat: str = "full") -> CellBytes:
    p = registry.param_count(cfg)
    dp = max(1, chips // model_shard)
    t = shape.global_batch * shape.seq_len
    d = cfg.d_model
    v = cfg.padded_vocab
    layers = cfg.num_layers + (cfg.num_encoder_layers
                               if cfg.family == "audio" else 0)

    # parameter passes: every DP replica reads its TP shard
    param_pass = p * param_bytes * dp
    param_reads_train = (2 + (1 if remat == "full" else 0)) * param_pass
    grad_rw = 2 * p * 4 * dp                        # write + optimizer read (f32)
    opt_factor = 1 if zero1 else dp                 # ZeRO-1 shards state over dp
    opt_rw = 2 * opt_slots * p * 4 * opt_factor     # read + write, f32 slots
    ema_rw = 2 * p * 4 * opt_factor
    param_write = param_pass

    # activations: ~8 residual-stream R/W per layer (pre-norm block: 2 norms,
    # attn in/out, mlp in/out, 2 residual adds), 2-byte activations, x2 for
    # the backward pass streams
    act = 8 * t * d * 2 * layers * 2
    # logits: produced + consumed fwd, recomputed in bwd (chunked CE)
    logits = 2 * t * v * 4 * 2

    train = (param_reads_train + grad_rw + opt_rw + ema_rw + param_write
             + act + logits)
    fwd = param_pass + 8 * t * d * 2 * layers
    cache = _cache_total_bytes(cfg, shape)
    decode = param_pass + cache * 1.02              # read cache + tiny write
    return CellBytes(train=train, fwd=fwd, decode=decode, cache_bytes=cache)
