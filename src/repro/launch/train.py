"""Training launcher CLI — one entry point for every coordination regime.

    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --strategy backup --workers 6 --backups 2 [--resume]
    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --strategy async --workers 6
    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --strategy softsync --workers 6 --softsync-c 2
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --strategy backup --workers 6 --backups 2 \
        --execution spmd --mesh-data 8 --chunk-size 8

--smoke uses the reduced per-arch config (CPU-runnable); without it the
full published config is built (TPU-scale — on this host use the dry-run
instead). Everything routes through ``repro.train.loop.run_experiment``:
mask strategies (backup/full_sync/timeout/dynamic_backup) drive the
straggler simulator and the masked SPMD step; event strategies
(async/softsync) drive the discrete-event parameter server — both with
the paper's lr rule, EMA, atomic checkpoints, and the unified metrics
schema (docs/api.md).

Chaos engineering (docs/robustness.md): ``--faults`` attaches a seeded
fault plan, ``--supervise`` routes the run through the recovery
supervisor so injected crashes/preemptions restore-and-continue:

    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --strategy backup --workers 6 --backups 2 \
        --faults 'crash@10:w2,slow@5:w0,preempt@30' --supervise
"""
from __future__ import annotations

import argparse
import os

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, FaultConfig, OptimizerConfig,
                                ShapeConfig, TrainConfig)
from repro.core.straggler import PaperCalibrated
from repro.train.loop import run_experiment

MASK_STRATEGIES = ("backup", "full_sync", "timeout", "dynamic_backup")
EVENT_STRATEGIES = ("async", "softsync")


def _resolved_workers(args):
    """(backups, total launched) after defaults — the ONE definition both
    build_config and the arg validation use."""
    with_backups = args.strategy in ("backup", "dynamic_backup")
    backups = args.backups if args.backups is not None else (
        2 if with_backups else 0)
    total = args.workers + (backups if with_backups else 0)
    return backups, total


def build_config(args) -> TrainConfig:
    """args -> TrainConfig, with strategy-specific arg validation."""
    model_cfg = (configs.get_smoke_config(args.arch) if args.smoke
                 else configs.get_config(args.arch))
    backups, total = _resolved_workers(args)
    deadline = args.deadline if args.deadline is not None else 2.0
    softsync_c = args.softsync_c if args.softsync_c is not None else 2
    return TrainConfig(
        model=model_cfg,
        shape=ShapeConfig("cli", args.seq, args.batch_per_worker * total,
                          "train"),
        aggregation=AggregationConfig(strategy=args.strategy,
                                      num_workers=args.workers,
                                      backup_workers=backups,
                                      deadline_s=deadline,
                                      softsync_c=softsync_c,
                                      dynamic_window=(args.dynamic_window
                                                      or 32),
                                      latency_source=args.latency_source),
        optimizer=OptimizerConfig(name=args.optimizer,
                                  learning_rate=args.lr,
                                  scale_lr_with_workers=True,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(directory=args.ckpt,
                                    every_steps=args.ckpt_every),
        execution=ExecutionConfig(backend=args.execution,
                                  mesh_data=args.mesh_data or 1,
                                  mesh_model=args.mesh_model or 1,
                                  grad_batch=args.grad_batch or 0,
                                  bucket_size=args.bucket_size or 0),
        seed=args.seed, total_steps=args.steps, log_every=10,
        chunk_size=args.chunk_size,
        straggler_backend=args.straggler_backend,
        prefetch_depth=args.prefetch_depth,
        faults=FaultConfig(spec=args.faults or "", seed=args.fault_seed,
                           supervise=args.supervise,
                           max_restarts=args.max_restarts))


def _validate(ap: argparse.ArgumentParser, args) -> None:
    """Reject argument combinations that would silently do nothing."""
    if args.backups is not None and args.strategy not in ("backup",
                                                          "dynamic_backup"):
        ap.error(f"--backups only applies to --strategy backup or "
                 f"dynamic_backup (got --strategy {args.strategy})")
    if args.dynamic_window is not None and args.strategy != "dynamic_backup":
        ap.error(f"--dynamic-window only applies to --strategy "
                 f"dynamic_backup (got --strategy {args.strategy})")
    if args.strategy == "dynamic_backup" and args.straggler_backend != "host":
        ap.error("--strategy dynamic_backup selects on the host (stateful "
                 "adaptation): --straggler-backend must be host")
    if args.latency_source != "sim" and args.strategy != "dynamic_backup":
        ap.error(f"--latency-source measured only applies to --strategy "
                 f"dynamic_backup (got --strategy {args.strategy})")
    for flag, value in (("--trace", args.trace),
                        ("--metrics", args.metrics)):
        if value is not None:
            parent = os.path.dirname(os.path.abspath(value))
            if not os.path.isdir(parent):
                ap.error(f"{flag} {value}: directory {parent} does not exist")
    if args.faults and args.straggler_backend != "host":
        ap.error("--faults composes with host-planned arrivals only: "
                 "--straggler-backend must be host")
    if args.deadline is not None and args.strategy != "timeout":
        ap.error(f"--deadline only applies to --strategy timeout "
                 f"(got --strategy {args.strategy})")
    if args.softsync_c is not None and args.strategy != "softsync":
        ap.error(f"--softsync-c only applies to --strategy softsync "
                 f"(got --strategy {args.strategy})")
    if args.strategy in EVENT_STRATEGIES and args.straggler_backend != "host":
        ap.error(f"--straggler-backend device only applies to mask "
                 f"strategies (got --strategy {args.strategy})")
    for flag, value in (("--mesh-data", args.mesh_data),
                        ("--mesh-model", args.mesh_model),
                        ("--grad-batch", args.grad_batch),
                        ("--bucket-size", args.bucket_size)):
        if value is not None and args.execution != "spmd":
            ap.error(f"{flag} only applies to --execution spmd")
    if args.execution == "spmd":
        if args.strategy in EVENT_STRATEGIES:
            ap.error(f"--execution spmd only applies to mask strategies "
                     f"(got --strategy {args.strategy})")
        if args.straggler_backend != "host":
            ap.error("--execution spmd consumes host-planned masks: "
                     "--straggler-backend must be host")
        _, total = _resolved_workers(args)
        if total % (args.mesh_data or 1):
            ap.error(f"total workers ({total}) must be divisible by "
                     f"--mesh-data ({args.mesh_data})")
        if args.grad_batch is not None:
            from repro.distributed.spmd_engine import validate_grad_batch
            try:
                validate_grad_batch(args.grad_batch,
                                    total // (args.mesh_data or 1))
            except ValueError as e:
                ap.error(f"--grad-batch: {e}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.list_archs(),
                    default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50,
                    help="training steps (PS updates for async/softsync)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--strategy", default="backup",
                    choices=list(MASK_STRATEGIES) + list(EVENT_STRATEGIES))
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--backups", type=int, default=None,
                    help="backup workers b (backup strategy only; default 2)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="aggregation deadline s (timeout strategy only; "
                         "default 2.0)")
    ap.add_argument("--softsync-c", type=int, default=None,
                    help="gradients averaged per update (softsync only; "
                         "default 2)")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--optimizer", default="rmsprop_momentum")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-size", type=int, default=1,
                    help="iterations fused per device dispatch — SPMD steps "
                         "for mask strategies, PS updates for event "
                         "strategies (1 = legacy per-step/per-arrival loop)")
    ap.add_argument("--straggler-backend", choices=["host", "device"],
                    default="host",
                    help="'device' samples arrivals/batches inside the scan")
    ap.add_argument("--execution", choices=["sim", "spmd"], default="sim",
                    help="'spmd' runs the workers over a real device mesh "
                         "(repro.distributed.spmd_engine, docs/spmd.md); "
                         "'sim' is the single-device simulated backend")
    ap.add_argument("--mesh-data", type=int, default=None,
                    help="devices on the mesh 'data' (worker) axis "
                         "(spmd only; total workers must divide evenly)")
    ap.add_argument("--mesh-model", type=int, default=None,
                    help="devices on the mesh 'model' axis (spmd only): "
                         "shards params/opt state/EMA and computes each "
                         "worker's gradient tensor-parallel (docs/spmd.md); "
                         "model dims must divide or the axis is carried "
                         "replicated")
    ap.add_argument("--grad-batch", type=int, default=None,
                    help="per-shard worker-gradient batching (spmd only): "
                         "0 = vmap all local workers (fast path), 1 = "
                         "sequential lax.map (lowest activation memory), "
                         "k = microbatches of k workers (must divide "
                         "total workers / mesh-data)")
    ap.add_argument("--bucket-size", type=int, default=None,
                    help="lanes of the flattened gradient per collective "
                         "in the fused bucketed reduce-then-psum (spmd "
                         "only; 0 = one psum carries gradient + metrics, "
                         "docs/spmd.md)")
    ap.add_argument("--platform", choices=["cpu", "gpu", "tpu"],
                    default=None,
                    help="pin the jax platform and apply its XLA flag "
                         "recipe before backend init (launch.mesh."
                         "set_platform; on gpu this enables async "
                         "collectives + the latency-hiding scheduler the "
                         "bucketed reduce-then-psum overlaps under)")
    ap.add_argument("--prefetch-depth", type=int, default=1,
                    help="chunks speculatively built ahead of the device "
                         "dispatch (chunked loop; 1 = double buffering)")
    ap.add_argument("--dynamic-window", type=int, default=None,
                    help="sliding window of steps the adaptive cutoff is "
                         "estimated over (dynamic_backup only; default 32)")
    ap.add_argument("--faults", default=None,
                    help="chaos plan spec, e.g. 'crash@10:w2,slow@5:w0,"
                         "ckpt_io@20,preempt@30' or 'crash=2,slow=3' for "
                         "seeded-random placement (docs/robustness.md)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for random fault placement and the "
                         "deterministic recovery log")
    ap.add_argument("--supervise", action="store_true",
                    help="run under the recovery supervisor: injected/real "
                         "crashes restore the last good checkpoint and "
                         "continue (repro.train.supervisor)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="supervisor restart budget before giving up")
    ap.add_argument("--latency-source", choices=["sim", "measured"],
                    default="sim",
                    help="where dynamic_backup's adaptation window comes "
                         "from: the straggler simulator's arrival model, or "
                         "fenced wall-clock per-worker step times measured "
                         "on the real mesh (docs/observability.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record host-side spans and export Chrome-trace "
                         "JSON here (load at ui.perfetto.dev); enables "
                         "block_until_ready fences at chunk edges")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="dump the unified metrics registry as JSONL here "
                         "(one object per metric; docs/observability.md)")
    args = ap.parse_args(argv)
    _validate(ap, args)

    if args.platform:
        from repro.launch import mesh as mesh_lib
        added = mesh_lib.set_platform(args.platform)
        if added:
            print(f"[train] XLA latency-hiding flags: {' '.join(added)}")
    cfg = build_config(args)
    tracer = metrics = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    resume = args.resume and os.path.exists(os.path.join(args.ckpt, "LATEST"))
    if resume:
        from repro.train import checkpoint as ckpt_lib
        print(f"[train] resumed at step {ckpt_lib.latest_step(args.ckpt)}")
    if args.supervise:
        from repro.train.supervisor import run_supervised
        res = run_supervised(cfg, latency=PaperCalibrated(), tracer=tracer,
                             metrics=metrics)
    else:
        res = run_experiment(cfg, latency=PaperCalibrated(), resume=resume,
                             save_final=True, tracer=tracer, metrics=metrics)
    for e in res.recovery_log:
        fields = " ".join(f"{k}={v}" for k, v in e.items() if k != "event")
        print(f"[train] recovery: {e['event']} {fields}")
    for m in res.metrics:
        print(f"[train] step {m['step']:5d} loss {m['loss']:.4f} "
              f"sim {m['sim_time']:8.1f}s selected {m['selected']} "
              f"staleness {m['staleness']:.1f}")
    print(f"[train] done: {res.steps} steps, sim_time {res.sim_time:.0f}s, "
          f"mean_selected {res.mean_selected:.2f}, "
          f"mean_staleness {res.mean_staleness:.2f}, "
          f"restarts {res.restarts}, checkpoint {args.ckpt}")
    if res.phase_times:
        breakdown = " ".join(f"{k} {v:.2f}s"
                             for k, v in sorted(res.phase_times.items()))
        print(f"[train] wall {res.wall_time_s:.2f}s ({breakdown})")
    if tracer is not None:
        tracer.export(args.trace)
        print(f"[train] trace: {args.trace} ({len(tracer)} events, "
              f"{tracer.dropped} dropped)")
    if metrics is not None:
        metrics.dump_jsonl(args.metrics)
        print(f"[train] metrics: {args.metrics} ({len(metrics)} series)")


if __name__ == "__main__":
    main()
