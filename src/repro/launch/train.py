"""Training launcher CLI.

    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --strategy backup --workers 6 --backups 2 [--resume]

--smoke uses the reduced per-arch config (CPU-runnable); without it the
full published config is built (TPU-scale — on this host use the dry-run
instead). The loop drives the straggler simulator, masked sync-backup
aggregation, RMSProp+momentum with the paper's lr rule, EMA, atomic
checkpoints, and elastic rescale on worker failures.
"""
from __future__ import annotations

import argparse

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import PaperCalibrated
from repro.train.loop import Trainer


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.list_archs(),
                    default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--strategy", choices=["backup", "full_sync", "timeout"],
                    default="backup")
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--backups", type=int, default=2)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--optimizer", default="rmsprop_momentum")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-size", type=int, default=1,
                    help="steps fused per device dispatch (1 = legacy loop)")
    ap.add_argument("--straggler-backend", choices=["host", "device"],
                    default="host",
                    help="'device' samples arrivals/batches inside the scan")
    args = ap.parse_args(argv)

    model_cfg = (configs.get_smoke_config(args.arch) if args.smoke
                 else configs.get_config(args.arch))
    total = args.workers + (args.backups if args.strategy == "backup" else 0)
    cfg = TrainConfig(
        model=model_cfg,
        shape=ShapeConfig("cli", args.seq, args.batch_per_worker * total,
                          "train"),
        aggregation=AggregationConfig(strategy=args.strategy,
                                      num_workers=args.workers,
                                      backup_workers=args.backups,
                                      deadline_s=args.deadline),
        optimizer=OptimizerConfig(name=args.optimizer,
                                  learning_rate=args.lr,
                                  scale_lr_with_workers=True,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(directory=args.ckpt,
                                    every_steps=args.ckpt_every),
        seed=args.seed, log_every=10, chunk_size=args.chunk_size,
        straggler_backend=args.straggler_backend)

    tr = Trainer(cfg, latency=PaperCalibrated())
    import os
    if args.resume and os.path.exists(os.path.join(args.ckpt, "LATEST")):
        tr.restore_checkpoint()
        print(f"[train] resumed at step {tr.step}")
    else:
        tr.init_state()
    res = tr.run(args.steps)
    for m in res.metrics:
        print(f"[train] step {m['step']:5d} loss {m['loss']:.4f} "
              f"sim {m['sim_time']:8.1f}s selected {m['selected']}")
    tr.save_checkpoint()
    print(f"[train] done: {res.steps} steps, sim_time {res.sim_time:.0f}s, "
          f"restarts {res.restarts}, checkpoint {args.ckpt}")


if __name__ == "__main__":
    main()
