import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full-size model (ShapeDtypeStruct stand-ins,
no allocation), attaches the production sharding rules, lowers and compiles
the train/prefill/decode step for the 16x16 single-pod mesh and the 2x16x16
multi-pod mesh, and records:

  * memory_analysis()      — per-device bytes (proves it fits)
  * cost_analysis()        — HLO flops / bytes (roofline numerator)
  * the collective schedule — every all-reduce/all-gather/reduce-scatter/
    all-to-all/collective-permute parsed from the optimized HLO with its
    payload bytes (roofline collective term)

Results go to experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json and
are consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import gc
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import SHAPES_BY_NAME, replace
from repro.core import ema as ema_lib
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import get_model, registry
from repro.optim import make_optimizer, schedules
from repro.optim.optimizers import rmsprop_momentum
from repro.train import serve_step as serve_lib
from repro.train import train_step as train_lib

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<sig>\([^)]*\)|\S+)\s+(?P<op>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")
_COMP_RE = re.compile(r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?(?P<cond>[\w.\-]+),\s*body=%?(?P<body>[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(?P<n>\d+)"\}')


def _shape_bytes(sig: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_DEF_RE = re.compile(r"^%?(?P<name>[\w.\-]+)\s+=\s+(?P<sig>\([^)]*\)|\S+)\s+\w")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Collective payload bytes from optimized HLO, with while-loop bodies
    multiplied by their known_trip_count (XLA's cost_analysis counts loop
    bodies ONCE — verified in tests/test_spmd_subprocess.py — so a naive
    grep undercounts scanned-layer collectives by ~num_layers).

    Records both result and operand payloads: all-gather results exceed
    their operands, reduce-scatter operands exceed their results; the wire
    model in benchmarks.roofline uses max(result, operands) per op.
    """
    # 1. split into computations; build a name -> bytes symbol table
    comp_colls: Dict[str, list] = {}
    comp_whiles: Dict[str, list] = {}
    defs: Dict[str, int] = {}
    entry = None
    current = None
    pending: Dict[str, list] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" "):
            m = _COMP_RE.match(stripped)
            if m:
                current = m.group("name")
                comp_colls.setdefault(current, [])
                comp_whiles.setdefault(current, [])
                pending.setdefault(current, [])
                if m.group("entry"):
                    entry = current
                continue
        if current is None:
            continue
        dm = _DEF_RE.match(stripped.removeprefix("ROOT ").strip())
        if dm:
            defs[dm.group("name")] = _shape_bytes(dm.group("sig"))
        wm = _WHILE_RE.search(stripped)
        if wm:
            tm = _TRIP_RE.search(stripped)
            trips = int(tm.group("n")) if tm else 1
            comp_whiles[current].append((wm.group("body"), wm.group("cond"), trips))
        cm = _COLL_RE.search(stripped)
        if cm and cm.group("suffix") != "-done":   # count start, not done
            res_bytes = _shape_bytes(cm.group("sig"))
            om = _OPERANDS_RE.search(stripped[cm.end() - 1:])
            operands = re.findall(r"%([\w.\-]+)", om.group(1)) if om else []
            pending[current].append((cm.group("op"), res_bytes, operands))

    # resolve operand byte sizes now that the symbol table is complete
    for comp, items in pending.items():
        for kind, res_bytes, operands in items:
            op_bytes = sum(defs.get(o, 0) for o in operands)
            comp_colls[comp].append((kind, res_bytes, op_bytes))

    # 2. resolve execution multiplicity from ENTRY through nested whiles
    mult: Dict[str, float] = {}

    def visit(name: str, m: float) -> None:
        mult[name] = mult.get(name, 0.0) + m
        for body, cond, trips in comp_whiles.get(name, []):
            visit(body, m * trips)
            visit(cond, m * (trips + 1))

    if entry:
        visit(entry, 1.0)

    # 3. aggregate
    per_kind: Dict[str, Dict[str, float]] = {}
    for comp, colls in comp_colls.items():
        m = mult.get(comp, 0.0)
        if m == 0.0 or not colls:
            continue
        for kind, res_bytes, op_bytes in colls:
            d = per_kind.setdefault(kind, {"count": 0.0, "bytes": 0.0,
                                           "wire_bytes": 0.0})
            d["count"] += m
            d["bytes"] += m * res_bytes
            d["wire_bytes"] += m * max(res_bytes, op_bytes)
    return {"per_kind": per_kind,
            "total_bytes": sum(d["bytes"] for d in per_kind.values()),
            "total_wire_bytes": sum(d["wire_bytes"]
                                    for d in per_kind.values()),
            "num_ops": sum(d["count"] for d in per_kind.values())}


def cost_analysis(compiled) -> Dict[str, Any]:
    """compiled.cost_analysis() across jax versions: older jax returns a
    one-dict-per-device list, newer returns the dict directly."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze(compiled, lower_s: float, compile_s: float) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
        },
        "collectives": coll,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
    }


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------


def _mesh_and_cfg(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = names.get("pod", 1) * names.get("data", 1)
    return mesh, dp


def model_config(arch: str, *, remat: Optional[str] = None,
                 moe_mode: Optional[str] = None):
    cfg = configs.get_config(arch)
    if remat:
        cfg = replace(cfg, remat=remat)
    if moe_mode and cfg.moe.enabled:
        cfg = replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                   partition_mode=moe_mode))
    return cfg


def train_policy(cfg, shape, mesh) -> Dict[str, Any]:
    """Auto-select the scale features needed for this cell to fit v5e HBM.

    * fsdp: shard params over data when the per-device TP shard > 2 GB
    * sp:   sequence-parallel activations for scan/attention families
    * microbatches: cap per-device saved-carry activations at ~1 GB
    """
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = names.get("model", 1)
    dp = int(np.prod([v for k, v in names.items() if k in ("pod", "data")]))
    p = registry.param_count(cfg)
    param_gb = p * 2 / model_size / 1e9
    fsdp = param_gb > 2.0
    sp = (cfg.family in ("dense", "moe", "vlm", "audio")
          and shape.seq_len % model_size == 0)
    layers = cfg.num_layers + (cfg.num_encoder_layers
                               if cfg.family == "audio" else 0)
    local_batch = max(1, shape.global_batch // dp)
    act_bytes = (layers * local_batch * shape.seq_len * cfg.d_model * 2
                 / (model_size if sp else 1))
    # dense-attention scores [B_mb, H/model, S, S] f32 also scale 1/micro
    score_bytes = 0.0
    if cfg.family != "ssm" and shape.seq_len <= 8192:
        heads_local = max(1, cfg.num_heads // model_size)
        score_bytes = local_batch * heads_local * shape.seq_len ** 2 * 4
    micro = 1
    while (act_bytes + score_bytes) / micro > 5e8 and micro < local_batch:
        micro *= 2
    # EMA is an EVAL artifact (paper evaluates on \bar theta); for >20B
    # params the f32 shadow moves to the host checkpoint/eval path instead
    # of occupying HBM in the train step.
    ema_device = p <= 20e9
    return {"fsdp": fsdp, "sp": sp, "microbatches": micro,
            "ema_device": ema_device}


def lower_train(cfg, shape, mesh, num_workers: int, *, zero1: bool = True,
                ema: bool = True, donate: bool = True,
                policy: Optional[Dict[str, Any]] = None):
    from repro.distributed.context import sequence_parallel
    policy = policy if policy is not None else train_policy(cfg, shape, mesh)
    ema = ema and policy.get("ema_device", True)
    model = get_model(cfg)
    opt = rmsprop_momentum(schedules.constant(0.045 * num_workers))

    params_t = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_t = jax.eval_shape(opt.init, params_t)
    ema_t = jax.eval_shape(ema_lib.init, params_t) if ema else None
    specs = train_lib.input_specs(cfg, shape, num_workers=num_workers)

    p_sh = sharding.param_shardings(cfg, mesh, params_t,
                                    fsdp=policy.get("fsdp", False))
    g_sh = (sharding.grad_shardings(cfg, mesh, params_t)
            if policy.get("zero2", True) else None)
    o_sh = sharding.opt_state_shardings(cfg, mesh, opt_t, zero1=zero1)
    e_sh = sharding.opt_state_shardings(cfg, mesh, ema_t, zero1=zero1) if ema else None
    b_sh = sharding.batch_shardings(mesh, specs["batch"])
    scalar = sharding.batch_shardings(mesh, specs["step"])
    mask_sh = sharding.batch_shardings(mesh, specs["mask"])

    step_fn = train_lib.build_train_step(
        model, opt, num_workers=num_workers, n_aggregate=num_workers,
        ema_decay=0.9999 if ema else 0.0,
        num_microbatches=policy.get("microbatches", 1),
        grad_shardings=g_sh)

    in_sh = (p_sh, o_sh, e_sh, scalar, b_sh, mask_sh)
    out_sh = (p_sh, o_sh, e_sh, None)
    jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1, 2) if donate else ())
    from repro.distributed.context import (layer_param_constraints,
                                           moe_data_sharding)
    constrainer = (sharding.layer_param_constrainer(
        cfg, mesh, fsdp=policy.get("fsdp", False))
        if policy.get("layer_constraints", True) else None)
    with use_mesh(mesh), sequence_parallel(policy.get("sp", False)), \
            layer_param_constraints(constrainer), moe_data_sharding(True):
        return jitted.lower(params_t, opt_t, ema_t, specs["step"],
                            specs["batch"], specs["mask"])


def _serve_fsdp(cfg, mesh) -> bool:
    """Weight-gather-per-layer (ZeRO-inference) when the TP shard alone
    exceeds ~2 GB/device (command-r-plus: kv=8 caps useful TP at 16)."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    return registry.param_count(cfg) * 2 / names.get("model", 1) > 2e9


def lower_prefill(cfg, shape, mesh):
    from repro.distributed.context import moe_data_sharding, sequence_parallel
    model = get_model(cfg)
    params_t = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = serve_lib.prefill_input_specs(cfg, shape)
    p_sh = sharding.param_shardings(cfg, mesh, params_t,
                                    fsdp=_serve_fsdp(cfg, mesh))
    b_sh = sharding.batch_shardings(mesh, specs["batch"])
    fn = serve_lib.build_prefill(model)
    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
    # NOTE: no sequence-parallel here — SP pays off for remat-SAVED
    # activations in training; forward-only prefill frees each layer's
    # activations, and an S-sharded residual conflicts with the chunked
    # attention layout (GSPMD falls back to replication).
    with use_mesh(mesh), moe_data_sharding(True):
        return jitted.lower(params_t, specs["batch"])


def lower_decode(cfg, shape, mesh, cache_dtype=None):
    model = get_model(cfg)
    params_t = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = serve_lib.decode_input_specs(model, cfg, shape,
                                         cache_dtype=cache_dtype)
    p_sh = sharding.param_shardings(cfg, mesh, params_t,
                                    fsdp=_serve_fsdp(cfg, mesh))
    c_sh = sharding.cache_shardings(cfg, mesh, specs["cache"])
    t_sh = sharding.batch_shardings(mesh, {"t": specs["token"]})["t"]
    fn = serve_lib.build_decode_step(model)
    jitted = jax.jit(fn, in_shardings=(p_sh, t_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
    with use_mesh(mesh):
        return jitted.lower(params_t, specs["token"], specs["cache"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             zero1: bool = True, remat: Optional[str] = None,
             moe_mode: Optional[str] = None, tag: str = "",
             policy_override: Optional[Dict[str, Any]] = None,
             out_dir: str = OUT_DIR) -> Dict[str, Any]:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    shape = SHAPES_BY_NAME[shape_name]
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    if configs.cell_is_skipped(arch, shape_name):
        result["status"] = "skipped"
        result["reason"] = ("pure full-attention arch; long_500k requires "
                            "sub-quadratic attention (DESIGN.md)")
        _save(out_dir, cell_id, result)
        return result

    cfg = model_config(arch, remat=remat, moe_mode=moe_mode)
    mesh, dp = _mesh_and_cfg(multi_pod)
    result["devices"] = int(np.prod(mesh.devices.shape))
    result["params"] = registry.param_count(cfg)
    result["active_params"] = registry.param_count(cfg, active_only=True)
    t0 = time.time()
    try:
        if shape.kind == "train":
            policy = dict(train_policy(cfg, shape, mesh), **(policy_override or {}))
            result["policy"] = {**policy, "zero1": zero1}
            lowered = lower_train(cfg, shape, mesh, dp, zero1=zero1,
                                  policy=policy)
        elif shape.kind == "prefill":
            lowered = lower_prefill(cfg, shape, mesh)
        else:
            cache_dtype = jnp.int8 if (policy_override or {}).get("cache_int8") \
                else None
            result["policy"] = {"cache_int8": cache_dtype is not None}
            lowered = lower_decode(cfg, shape, mesh, cache_dtype=cache_dtype)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        result.update(analyze(compiled, t1 - t0, t2 - t1))
        result["status"] = "ok"
        print(f"[dryrun] {cell_id}: OK "
              f"(lower {t1-t0:.1f}s compile {t2-t1:.1f}s "
              f"flops={result['cost']['flops']:.3e} "
              f"coll={result['collectives']['total_bytes']:.3e}B)")
        del compiled, lowered
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cell_id}: FAIL {type(e).__name__}: {e}")
    gc.collect()
    _save(out_dir, cell_id, result)
    return result


def _save(out_dir: str, cell_id: str, result: Dict[str, Any]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(result, f, indent=2, default=float)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.list_archs())
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-zero1", action="store_true",
                    help="ablation: replicated optimizer state")
    ap.add_argument("--remat", choices=["none", "full", "dots"])
    ap.add_argument("--moe-mode", choices=["tp", "ep"])
    ap.add_argument("--microbatch", type=int, help="override auto microbatches")
    ap.add_argument("--fsdp", choices=["on", "off"], help="override auto FSDP")
    ap.add_argument("--sp", choices=["on", "off"],
                    help="override sequence-parallel activations")
    ap.add_argument("--no-zero2", action="store_true",
                    help="ablation: all-reduce grads instead of reduce-scatter")
    ap.add_argument("--cache-int8", action="store_true",
                    help="decode shapes: int8-quantized KV cache")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in configs.list_archs():
            for shape in SHAPES_BY_NAME:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all")
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            cid = f"{arch}__{shape}__{'multi' if mp else 'single'}" + \
                (f"__{args.tag}" if args.tag else "")
            path = os.path.join(args.out, cid + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue
            override: Dict[str, Any] = {}
            if args.microbatch:
                override["microbatches"] = args.microbatch
            if args.fsdp:
                override["fsdp"] = args.fsdp == "on"
            if args.sp:
                override["sp"] = args.sp == "on"
            if args.no_zero2:
                override["zero2"] = False
            if args.cache_int8:
                override["cache_int8"] = True
            r = run_cell(arch, shape, mp, zero1=not args.no_zero1,
                         remat=args.remat, moe_mode=args.moe_mode,
                         tag=args.tag, policy_override=override or None,
                         out_dir=args.out)
            failures += r["status"] == "error"
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
