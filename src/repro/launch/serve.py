"""Serving launcher CLI: batched prefill + greedy decode on a smoke config.

    python -m repro.launch.serve --arch gemma3-1b --batch 4 --tokens 16 \
        [--cache-int8]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import get_model


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.list_archs(),
                    default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-int8", action="store_true",
                    help="int8-quantized KV cache (decode memory lever)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    max_len = args.prompt_len + args.tokens + 1
    cache_dtype = jnp.int8 if args.cache_int8 else None
    cache = model.init_cache(args.batch, max_len, cache_dtype)
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_seq_len,
                                    cfg.d_model))
        cache = model.prime_cross_cache(params, cache, frames)

    step = jax.jit(model.decode_step)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, prompt[:, i:i + 1], cache)
    prefill_s = time.time() - t0
    t0 = time.time()
    tok = jnp.argmax(logits, -1)[:, None].astype(prompt.dtype)
    outs = []
    for _ in range(args.tokens):
        outs.append(tok)
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(prompt.dtype)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    out = jnp.concatenate(outs, axis=1)
    print(f"[serve] {args.arch} cache={'int8' if args.cache_int8 else cfg.dtype}"
          f" prefill {prefill_s:.2f}s, decode {args.tokens} toks x "
          f"{args.batch} seqs in {decode_s:.2f}s "
          f"({args.batch * args.tokens / max(decode_s, 1e-9):.1f} tok/s host)")
    for i in range(args.batch):
        print(f"  {list(map(int, out[i]))}")


if __name__ == "__main__":
    main()
