"""Serving launcher CLI: continuous batching over the paged KV cache.

    # replay a seeded open-loop trace through the serve engine
    python -m repro.launch.serve --arch qwen3-0.6b --requests 16 --rate 8 \
        [--policy continuous|static] [--cache-int8] [--mesh-model 2] \
        [--restore /path/to/ckpt [--step N] [--ema]] [--faults slowdown@4]

    # replica router: hedging, SLO admission, replica-scope chaos
    python -m repro.launch.serve --arch qwen3-0.6b --replicas 3 \
        --hedge-after 6 --timeout 40 --slo-p99-ms 20 \
        --faults 'slowdown@0:r0:x8:d32,crash@10:r1,restart@30:r1'

    # legacy toy path (static batch, contiguous cache)
    python -m repro.launch.serve --arch gemma3-1b --toy --batch 4 --tokens 16

The default path builds a :class:`repro.serve.ServeEngine` (docs/
serving.md): bucketed prefill, paged decode, admission/eviction at
decode-step granularity, optional TP-sharded decode over the mesh 'model'
axis, optional chaos injection. ``--restore`` serves a trained checkpoint
(replicated, TP-sharded, or sim) through the verified restore bridge.
``--replicas N`` (N > 1) fronts N replica sessions with the
:class:`repro.serve.ReplicaRouter` on the deterministic virtual clock
(docs/robustness.md "Serving resilience"): ``--faults`` then takes the
replica-scope grammar (``kind@step:rN``).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import get_model


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", choices=configs.list_archs(),
                    default="qwen3-0.6b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-int8", action="store_true",
                    help="int8-quantized KV (per-page scale tables)")
    # -- engine path ---------------------------------------------------------
    ap.add_argument("--requests", type=int, default=16,
                    help="trace length (open-loop arrivals)")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="offered load: aggregate arrivals per second")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (power of two)")
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16,
                    help="per-request token budget cap")
    ap.add_argument("--policy", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="TP-shard decode over the mesh 'model' axis")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas page-gather kernel (native on TPU, "
                    "interpret elsewhere)")
    ap.add_argument("--faults", default="",
                    help="chaos spec, slowdown/preempt kinds only "
                    "(e.g. 'slowdown@4:w0,preempt@9')")
    # -- replica router -------------------------------------------------------
    ap.add_argument("--replicas", type=int, default=1,
                    help="front N replica sessions with the router "
                    "(virtual clock; --faults takes kind@step:rN)")
    ap.add_argument("--hedge-after", type=float, default=None,
                    help="[router] hedge stragglers past max(windowed p95, "
                    "this floor) virtual units")
    ap.add_argument("--timeout", type=float, default=None,
                    help="[router] per-attempt deadline before a jittered "
                    "backoff retry")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="SLO: windowed-p99 latency target. With --replicas "
                    "> 1 the router gates on its virtual clock (1 unit = "
                    "1 ms); with one replica the engine gates on measured "
                    "wall-clock seconds (docs/observability.md)")
    ap.add_argument("--slo-mode", choices=("shed", "queue"), default="shed",
                    help="action while the SLO is violated")
    ap.add_argument("--restore", default="",
                    help="checkpoint dir: serve trained weights via the "
                    "verified restore bridge")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest good)")
    ap.add_argument("--ema", action="store_true",
                    help="serve the EMA weights from the checkpoint")
    # -- legacy toy path -----------------------------------------------------
    ap.add_argument("--toy", action="store_true",
                    help="legacy static-batch toy path (contiguous cache)")
    ap.add_argument("--batch", type=int, default=4, help="[toy] batch size")
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="[toy] prompt length")
    ap.add_argument("--tokens", type=int, default=16,
                    help="[toy] tokens to decode")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record prefill/decode/admit/evict (and router "
                    "dispatch/hedge/timeout/failover) spans, exported as "
                    "Chrome-trace JSON (load at ui.perfetto.dev)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="dump the unified metrics registry as JSONL "
                    "(one object per metric; docs/observability.md)")
    return ap


def _validate(args) -> None:
    if args.toy and (args.restore or args.mesh_model > 1 or args.faults):
        raise SystemExit("--toy is the legacy static path: it has no "
                         "--restore/--mesh-model/--faults support")
    if args.step is not None and not args.restore:
        raise SystemExit("--step needs --restore")
    if args.ema and not args.restore:
        raise SystemExit("--ema needs --restore")
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.replicas == 1:
        for flag, val in (("--hedge-after", args.hedge_after),
                          ("--timeout", args.timeout)):
            if val is not None:
                raise SystemExit(f"{flag} needs --replicas > 1 "
                                 "(the router path)")
        if args.slo_p99_ms is not None and args.toy:
            raise SystemExit("--slo-p99-ms has no --toy support (the gate "
                             "lives in the serve engine / router)")
    elif args.toy or args.policy == "static":
        raise SystemExit("--replicas > 1 is the router path: continuous "
                         "policy only, no --toy")
    for flag, value in (("--trace", args.trace),
                        ("--metrics", args.metrics)):
        if value is None:
            continue
        if args.toy:
            raise SystemExit(f"{flag} has no --toy support (spans live in "
                             "the serve engine / router)")
        parent = os.path.dirname(os.path.abspath(value))
        if not os.path.isdir(parent):
            raise SystemExit(f"{flag} {value}: directory {parent} "
                             "does not exist")


def _toy_main(args, cfg, model, params) -> None:
    from repro.train.serve_step import bucketed_max_len
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    # power-of-two cache bucket: mixed prompt lengths reuse one compile
    max_len = bucketed_max_len(args.prompt_len + args.tokens + 1)
    cache_dtype = jnp.int8 if args.cache_int8 else None
    cache = model.init_cache(args.batch, max_len, cache_dtype)
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_seq_len,
                                    cfg.d_model))
        cache = model.prime_cross_cache(params, cache, frames)

    step = jax.jit(model.decode_step)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, prompt[:, i:i + 1], cache)
    prefill_s = time.time() - t0
    t0 = time.time()
    tok = jnp.argmax(logits, -1)[:, None].astype(prompt.dtype)
    outs = []
    for _ in range(args.tokens):
        outs.append(tok)
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(prompt.dtype)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    out = jnp.concatenate(outs, axis=1)
    print(f"[serve] {args.arch} cache={'int8' if args.cache_int8 else cfg.dtype}"
          f" prefill {prefill_s:.2f}s, decode {args.tokens} toks x "
          f"{args.batch} seqs in {decode_s:.2f}s "
          f"({args.batch * args.tokens / max(decode_s, 1e-9):.1f} tok/s host)")
    for i in range(args.batch):
        print(f"  {list(map(int, out[i]))}")


def _router_main(args, engine, trace, tracer=None, metrics=None) -> None:
    from repro.serve import ReplicaRouter, RouterConfig, SLOConfig
    slo = None
    if args.slo_p99_ms is not None:
        slo = SLOConfig(target_p99=args.slo_p99_ms, mode=args.slo_mode)
    router = ReplicaRouter(
        engine,
        RouterConfig(num_replicas=args.replicas, timeout=args.timeout,
                     hedge_after=args.hedge_after, seed=args.seed,
                     faults=args.faults or None, fault_seed=args.seed),
        slo=slo, tracer=tracer, metrics=metrics)
    report = router.run(trace)
    m = report.metrics
    print(f"[serve] {args.arch} router replicas={args.replicas} "
          f"slots={args.slots}x{args.replicas}"
          f"{f' hedge>{args.hedge_after}' if args.hedge_after else ''}"
          f"{f' timeout={args.timeout}' if args.timeout else ''}"
          f"{f' slo-p99={args.slo_p99_ms}({args.slo_mode})' if slo else ''}")
    print(f"  {m['completed']}/{m['total']} completed, {m['rejected']} "
          f"rejected, {m['lost_requests']} lost in {m['duration']:.1f} "
          f"virtual units -> goodput {m['goodput']:.3f} req/unit")
    print(f"  latency p50 {m['p50_latency']:.2f} p99 {m['p99_latency']:.2f}"
          f" | hedges {m['hedges']} (won {m['hedge_wins']})"
          f" | retries {m['retries']} | drained {m['drained']}"
          f" | crashes {m['crashes']} preempts {m['preempts']} "
          f"restarts {m['restarts']}")
    for ev in report.health:
        print(f"  health: {ev}")
    for rej in report.rejected[:4]:
        print(f"  rejected: {rej}")
    for c in report.completed[:4]:
        print(f"  rid={c.rid} replica={c.replica}"
              f"{' hedged' if c.hedged else ''} {c.tokens}")


def main(argv=None) -> None:
    args = _build_parser().parse_args(argv)
    _validate(args)
    cfg = configs.get_smoke_config(args.arch)
    model = get_model(cfg)
    if args.restore:
        from repro.serve import restore_params
        params, manifest = restore_params(args.restore, cfg, step=args.step,
                                          use_ema=args.ema)
        print(f"[serve] restored step {manifest['step']} from {args.restore}"
              f"{' (ema)' if args.ema else ''}")
    else:
        params = model.init(jax.random.PRNGKey(args.seed))
    if args.toy:
        _toy_main(args, cfg, model, params)
        return

    from repro.serve import ServeEngine, SLOConfig, TraceConfig, make_trace
    tracer = metrics = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    engine_slo = None
    if args.slo_p99_ms is not None and args.replicas == 1:
        # single-replica path: the gate runs inside the engine on its
        # wall clock — the measured-latency SLO loop
        engine_slo = SLOConfig(target_p99=args.slo_p99_ms,
                               mode=args.slo_mode)
    engine = ServeEngine(
        cfg, params, num_slots=args.slots, page_size=args.page_size,
        max_prompt_len=args.max_prompt, max_new_cap=args.max_new,
        cache_int8=args.cache_int8, mesh_model=args.mesh_model,
        use_kernel=args.use_kernel,
        faults=None if args.replicas > 1 else (args.faults or None),
        fault_seed=args.seed,
        clock="virtual" if args.replicas > 1 else "wall",
        slo=engine_slo, tracer=tracer, metrics=metrics)
    trace = make_trace(TraceConfig(
        num_requests=args.requests, rate=args.rate,
        prompt_len_min=2, prompt_len_max=args.max_prompt,
        max_new_min=2, max_new_max=args.max_new,
        vocab=cfg.vocab_size, seed=args.seed))
    if args.replicas > 1:
        _router_main(args, engine, trace, tracer=tracer, metrics=metrics)
        _export_obs(args, tracer, metrics)
        return
    report = engine.run(trace, policy=args.policy)
    m = report.metrics
    print(f"[serve] {args.arch} policy={args.policy} slots={args.slots} "
          f"pages={engine.pool_cfg.num_pages}x{args.page_size}"
          f"{' int8' if args.cache_int8 else ''}"
          f"{f' tp={args.mesh_model}' if args.mesh_model > 1 else ''}")
    print(f"  {m['completed']} requests, {m['total_tokens']} tokens in "
          f"{m['duration']:.2f}s -> {m['tokens_per_s']:.1f} tok/s")
    print(f"  latency p50 {m['p50_latency']:.3f}s p99 {m['p99_latency']:.3f}s"
          f" | ttft p50 {m['p50_ttft']:.3f}s"
          f" | occupancy {m['mean_occupancy']:.2f}"
          f" | compiles prefill={m['prefill_compiles']} "
          f"decode={m['decode_compiles']}")
    if engine_slo is not None:
        print(f"  slo: shed {m['rejected_slo_shed']} trips {m['slo_trips']}"
              f" estimate {m['slo_estimate']:.3f}s")
    print(f"  wall {m['wall_time_s']:.2f}s (prefill {m['prefill_s']:.2f}s "
          f"decode {m['decode_s']:.2f}s)")
    for ev in report.events:
        print(f"  chaos: {ev}")
    for c in report.completed[:4]:
        print(f"  rid={c.rid} {c.tokens}")
    _export_obs(args, tracer, metrics)


def _export_obs(args, tracer, metrics) -> None:
    if tracer is not None:
        tracer.export(args.trace)
        print(f"[serve] trace: {args.trace} ({len(tracer)} events, "
              f"{tracer.dropped} dropped)")
    if metrics is not None:
        metrics.dump_jsonl(args.metrics)
        print(f"[serve] metrics: {args.metrics} ({len(metrics)} series)")


if __name__ == "__main__":
    main()
