"""Production mesh builders + the platform / XLA-flag recipe.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real single device).
"""
from __future__ import annotations

import os
from typing import List, Optional

import jax

try:                                   # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: Auto is the only mode
    AxisType = None


# The latency-hiding recipe (docs/spmd.md): async collectives + the
# latency-hiding scheduler let each bucket's psum from the fused
# bucketed reduce (kernels/bucketed_reduce) overlap the remaining
# per-worker gradient compute instead of serializing behind it.
# These are GPU flags: CPU/TPU XLA builds treat unknown --xla_gpu_*
# flags as a FATAL parse error, so they are only ever applied when the
# target platform is 'gpu' (or explicitly forced).
LATENCY_HIDING_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def enable_latency_hiding(*, platform: Optional[str] = None,
                          force: bool = False) -> List[str]:
    """Append the latency-hiding XLA flags to ``XLA_FLAGS`` (idempotent).

    Only takes effect before the first jax device query (XLA parses the
    env once at backend init), and only when ``platform == 'gpu'`` or
    ``force=True`` — see ``LATENCY_HIDING_FLAGS``. Returns the flags
    actually added, so callers can log what changed.
    """
    if platform != "gpu" and not force:
        return []
    flags = os.environ.get("XLA_FLAGS", "")
    added = [f for f in LATENCY_HIDING_FLAGS
             if f.split("=")[0] not in flags]
    if added:
        os.environ["XLA_FLAGS"] = " ".join([flags] + added).strip()
    return added


def set_platform(platform: str = "cpu", *,
                 latency_hiding: bool = True) -> List[str]:
    """Pin the jax platform and apply its XLA flag recipe.

    Call before any jax computation (the platform pin and ``XLA_FLAGS``
    both only take effect at backend init). On ``'gpu'`` this applies
    the latency-hiding flags the fused bucketed reduce-then-psum is
    shaped for; on ``'cpu'``/``'tpu'`` the flag recipe is a no-op (the
    flags are unknown to those XLA builds). Returns the flags added.
    """
    jax.config.update("jax_platform_name", platform)
    return enable_latency_hiding(platform=platform) if latency_hiding else []


def _mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return _mesh((data, model), ("data", "model"))


def use_mesh(mesh):
    """Context manager setting the ambient mesh, across jax versions:
    jax.set_mesh where it exists, else the Mesh's own context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
