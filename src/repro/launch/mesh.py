"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real single device).
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: Auto is the only mode
    AxisType = None


def _mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return _mesh((data, model), ("data", "model"))


def use_mesh(mesh):
    """Context manager setting the ambient mesh, across jax versions:
    jax.set_mesh where it exists, else the Mesh's own context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
