"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real single device).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
