"""Measured straggler tails as a latency model.

The source paper's case for backup workers rests on *measured*
per-worker step-time CDFs (its Figs. 3/4); everything in this repo so
far drives the adaptive machinery off simulated arrival models instead.
:class:`EmpiricalLatencyModel` closes that loop: the trainer records
fenced per-worker step times from the real mesh (one row per dispatch;
dead workers at ``+inf``), and the recorded distribution

* feeds ``DynamicBackup``'s cutoff adaptation directly
  (``latency_source='measured'`` — ``core/coordination.py``), and
* implements the simulator's ``LatencyModel`` protocol
  (``sample(rng, (iters, workers)) -> seconds``) by bootstrap
  resampling, so a measured tail can replace ``PaperCalibrated`` in any
  simulated experiment.

Duck-typed rather than subclassed: ``obs`` sits below ``core`` in the
layer order and imports nothing from it. State round-trips through
``state_dict``/``load_state_dict`` (JSON-able), which is how the model
survives inside ``DynamicBackup``'s checkpointed ``strategy_state``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.quantiles import windowed_quantile


class EmpiricalLatencyModel:
    """Per-worker ring of measured step times (seconds).

    ``record(row)`` folds one measured per-worker row; non-finite
    entries (dead workers arrive at ``+inf``) are counted but not
    stored, so the empirical distribution only ever contains real
    measurements. ``sample`` bootstraps per worker — a worker that has
    its own samples resamples them; one that does not (or a column
    beyond ``num_workers``) draws from the pooled distribution; until
    anything is recorded at all, ``fallback_s`` is returned (warmup).
    """

    def __init__(self, num_workers: int, window: int = 256,
                 fallback_s: float = 1.0):
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1 (got {num_workers})")
        if window < 1:
            raise ValueError(f"window must be >= 1 (got {window})")
        self.num_workers = int(num_workers)
        self.window = int(window)
        self.fallback_s = float(fallback_s)
        self.samples: List[List[float]] = [[] for _ in range(num_workers)]
        self.rows = 0                 # rows recorded (incl. dropped infs)
        self.dropped = 0              # non-finite entries seen

    def __len__(self) -> int:
        return sum(len(s) for s in self.samples)

    @property
    def warm(self) -> bool:
        return len(self) > 0

    def record(self, row: Sequence[float]) -> None:
        """Fold one measured per-worker step-time row (seconds; +inf for
        workers that produced nothing this step)."""
        row = np.asarray(row, np.float64).reshape(-1)
        self.rows += 1
        for w in range(min(len(row), self.num_workers)):
            v = float(row[w])
            if not np.isfinite(v):
                self.dropped += 1
                continue
            s = self.samples[w]
            s.append(v)
            if len(s) > self.window:
                s.pop(0)

    # -- the LatencyModel protocol (repro.core.straggler, duck-typed) --------

    def sample(self, rng: np.random.RandomState,
               shape: Tuple[int, ...]) -> np.ndarray:
        """Bootstrap-resample measured times into an [iters, workers]
        (or any trailing-workers) seconds array."""
        out = np.empty(shape, np.float64)
        flat = out.reshape(-1, shape[-1]) if len(shape) > 1 else \
            out.reshape(1, -1)
        pooled = [v for s in self.samples for v in s]
        # legacy RandomState (the straggler sim's rng) or a Generator
        draw = getattr(rng, "integers", None) or rng.randint
        for w in range(flat.shape[1]):
            src = (self.samples[w]
                   if w < self.num_workers and self.samples[w] else pooled)
            if not src:
                flat[:, w] = self.fallback_s
                continue
            idx = draw(0, len(src), size=flat.shape[0])
            flat[:, w] = np.asarray(src, np.float64)[idx]
        return out

    # -- summaries ------------------------------------------------------------

    def quantile(self, q: float, worker: Optional[int] = None,
                 default: float = 0.0) -> float:
        """Windowed percentile — pooled, or one worker's own tail."""
        vals = (self.samples[worker] if worker is not None
                else [v for s in self.samples for v in s])
        return windowed_quantile(vals, q, min_samples=1, default=default)

    def mean_row(self) -> np.ndarray:
        """Per-worker mean step time (fallback where unmeasured)."""
        return np.array([float(np.mean(s)) if s else self.fallback_s
                         for s in self.samples])

    # -- checkpointable state (JSON-able) ------------------------------------

    def state_dict(self) -> Dict:
        return {"num_workers": self.num_workers, "window": self.window,
                "fallback_s": self.fallback_s, "rows": int(self.rows),
                "dropped": int(self.dropped),
                "samples": [[float(v) for v in s] for s in self.samples]}

    def load_state_dict(self, d: Dict) -> None:
        saved = [[float(v) for v in s] for s in d["samples"]]
        # a rescale may change the worker count: keep what maps over
        self.samples = [[] for _ in range(self.num_workers)]
        for w in range(min(len(saved), self.num_workers)):
            self.samples[w] = saved[w][-self.window:]
        self.rows = int(d.get("rows", 0))
        self.dropped = int(d.get("dropped", 0))
        self.fallback_s = float(d.get("fallback_s", self.fallback_s))
