"""Unified metrics: counters / gauges / histograms behind one registry.

Before this layer, the trainer, the serve engine and the replica router
each kept an ad-hoc metrics dict with its own key conventions. The
:class:`MetricsRegistry` is the one schema: ``subsystem/name`` keys
(the canonical set in :data:`METRIC_NAMES`, drift-guarded against
docs/observability.md), three instrument kinds, a JSONL sink
(``dump_jsonl``) and an end-of-run ``summary()``.

Histograms keep a bounded window of recent observations
(:class:`repro.obs.quantiles.WindowedQuantile` — the same estimator the
SLO gate and the hedging trigger control on) plus exact running
count/sum/min/max, so quantiles reflect the recent past while totals
stay lossless.

Zero dependencies beyond numpy; no repro imports outside ``obs``.
"""
from __future__ import annotations

import json
from typing import Dict, Iterator, List, Optional, Tuple, Union

from repro.obs.quantiles import WindowedQuantile

# The canonical metric schema. Every name the built-in subsystems emit;
# the docs drift guard pins each into docs/observability.md.
METRIC_NAMES = (
    # train/loop.py
    "train/steps",            # counter: optimizer updates applied
    "train/wall_time_s",      # gauge: total wall-clock of run()
    "train/dispatch_s",       # gauge: time in device dispatch (+ fences)
    "train/data_s",           # gauge: time staging batches / prefetching
    "train/ckpt_s",           # gauge: time committing checkpoints
    "train/chunk_time_s",     # histogram: fenced per-chunk wall time
    "train/step_time_s",      # histogram: fenced per-step wall time
    # distributed/spmd_engine.py (via the trainer's measured feed)
    "spmd/worker_step_s",     # histogram: measured per-worker step time
    # serve/engine.py
    "serve/completed",        # counter
    "serve/rejected",         # counter (all structured reasons)
    "serve/slo_shed",         # counter: wall-clock SLO gate sheds
    "serve/tokens",           # counter: tokens produced
    "serve/latency",          # histogram: request latency (engine clock)
    "serve/ttft",             # histogram: time to first token
    "serve/prefill_s",        # histogram: wall time per prefill call
    "serve/decode_s",         # histogram: wall time per decode step
    "serve/wall_time_s",      # gauge: total wall-clock of run()
    # serve/router.py (virtual-clock units where time-valued)
    "router/completed",       # counter
    "router/rejected",        # counter
    "router/hedges",          # counter: backup copies issued
    "router/hedge_wins",      # counter: backups that beat the primary
    "router/timeouts",        # counter: attempts cancelled at deadline
    "router/retries",         # counter: timed-out attempts re-dispatched
    "router/drained",         # counter: failover requeues
    "router/latency",         # histogram: completed latency (virtual)
)


class Counter:
    """Monotonic count."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def summary(self) -> Dict:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins sample (plus ``add`` for accumulated durations)."""

    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> None:
        self.value += float(v)

    def summary(self) -> Dict:
        return {"kind": self.kind, "value": self.value}


class Histogram:
    """Running count/sum/min/max + windowed p50/p99 of recent samples."""

    __slots__ = ("name", "count", "total", "vmin", "vmax", "_window")
    kind = "histogram"

    def __init__(self, name: str, window: int = 1024):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._window = WindowedQuantile(window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self._window.observe(v)

    @property
    def values(self) -> List[float]:
        """The retained window (most recent samples, oldest first)."""
        return list(self._window.values)

    def quantile(self, q: float, default: float = 0.0) -> float:
        return self._window.estimate(default, quantile=q)

    def summary(self) -> Dict:
        if not self.count:
            return {"kind": self.kind, "count": 0}
        return {"kind": self.kind, "count": self.count,
                "mean": self.total / self.count,
                "min": self.vmin, "max": self.vmax,
                "p50": self.quantile(50.0), "p99": self.quantile(99.0)}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name -> instrument, one schema across train/SPMD/serve.

    ``counter``/``gauge``/``histogram`` are get-or-create and
    kind-checked: asking for an existing name as a different kind is an
    error (one schema means one type per name). Iteration is sorted by
    name, so summaries and JSONL dumps are deterministic.
    """

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterator[Tuple[str, Metric]]:
        return iter(sorted(self._metrics.items()))

    def _get(self, name: str, cls, **kwargs) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kwargs)
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} is a {m.kind}, not a "
                             f"{cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 1024) -> Histogram:
        return self._get(name, Histogram, window=window)

    # -- export ---------------------------------------------------------------

    def summary(self) -> Dict[str, Dict]:
        """End-of-run snapshot: {name: {kind, value | count/mean/...}}."""
        return {name: m.summary() for name, m in self}

    def dump_jsonl(self, path: str) -> str:
        """One JSON object per line per metric — the machine-readable
        sink behind the launchers' ``--metrics PATH``."""
        with open(path, "w") as f:
            for name, m in self:
                f.write(json.dumps({"name": name, **m.summary()},
                                   default=float) + "\n")
        return path


def load_jsonl(path: str) -> List[Dict]:
    """Read a ``dump_jsonl`` file back (round-trip tests / tooling)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
