"""Observability: tracing + metrics + measured latency (docs/observability.md).

Zero-dependency (numpy + stdlib) and at the bottom of the layer order:
``core``, ``distributed``, ``serve`` and ``train`` all import ``obs``,
never the reverse. The disabled path is free — pass ``tracer=None``
anywhere and :func:`as_tracer` substitutes the shared no-op
:data:`NULL` tracer.
"""
from repro.obs.latency import EmpiricalLatencyModel
from repro.obs.metrics import (
    METRIC_NAMES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    load_jsonl,
)
from repro.obs.quantiles import WindowedQuantile, windowed_quantile
from repro.obs.trace import (
    NULL,
    SPAN_NAMES,
    NullTracer,
    Tracer,
    as_tracer,
    load_trace,
    span_tree,
)

__all__ = [
    "EmpiricalLatencyModel",
    "METRIC_NAMES",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "load_jsonl",
    "WindowedQuantile",
    "windowed_quantile",
    "NULL",
    "SPAN_NAMES",
    "NullTracer",
    "Tracer",
    "as_tracer",
    "load_trace",
    "span_tree",
]
