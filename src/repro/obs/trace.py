"""Host-side tracer: nested spans, ring-buffered, Chrome-trace export.

The measurement substrate of the telemetry layer (docs/observability.md).
A :class:`Tracer` records *host wall-clock* spans via
``time.perf_counter_ns``; device work is bracketed by the callers with
``jax.block_until_ready`` fences **at chunk edges only**, so the fused
``lax.scan`` hot loop is never broken into per-step dispatches just to
be observable. Events live in a bounded ring (old events drop, the
``dropped`` counter records how many) and export as Chrome-trace JSON —
load the file at https://ui.perfetto.dev or chrome://tracing.

Disabled tracing must cost nothing: pass no tracer and every
instrumentation site sees :data:`NULL` — a singleton whose ``span()``
returns one shared no-op context manager (no allocation, no clock
read). The overhead test in ``tests/test_obs.py`` holds the no-op path
under 2% of the chunked training loop.

Span names are registered in :data:`SPAN_NAMES`; the docs drift guard
(``tests/test_docs.py``) keeps every name documented in
docs/observability.md. Zero dependencies: stdlib only.
"""
from __future__ import annotations

import collections
import json
import time
from typing import Any, Deque, Dict, List, Optional

# The span taxonomy: every name an instrumentation site emits. cat is
# the prefix; the drift guard pins each name into docs/observability.md.
SPAN_NAMES = (
    # train/loop.py
    "train/step",             # legacy per-step dispatch (chunk_size=1)
    "train/chunk",            # one fused K-step lax.scan dispatch
    "train/device_wait",      # block_until_ready fence at the chunk edge
    "train/data_wait",        # prefetcher / batch staging
    "train/ckpt_save",        # atomic checkpoint commit
    # distributed/spmd_engine.py
    "spmd/dispatch",          # jitted mesh step/chunk call (all shards)
    "spmd/collective_wait",   # block_until_ready: collectives + compute
    # serve/engine.py (+ StepSession)
    "serve/admit",            # admission: slot+pages grant, incl. prefill
    "serve/prefill",          # the jitted bucketed prefill call
    "serve/decode",           # one decode step over every active slot
    "serve/evict",            # instant: preempt evicted the batch
    # serve/router.py (instants on the virtual-clock event loop)
    "router/dispatch",        # primary copy dispatched to a replica
    "router/hedge",           # backup copy issued past the p95 threshold
    "router/timeout",         # attempt cancelled at its deadline
    "router/failover",        # unhealthy replica drained back to the queue
)


class _NullSpan:
    """Shared no-op context manager — the disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every method is a no-op, ``span()`` allocates
    nothing (returns one shared context manager)."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, cat: str = "", **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "", **args) -> None:
        pass

    def counter(self, name: str, value: float) -> None:
        pass

    def export(self, path: str) -> None:
        pass


NULL = NullTracer()


def as_tracer(tracer) -> Any:
    """None -> the shared no-op tracer; anything else passes through."""
    return NULL if tracer is None else tracer


class _Span:
    """One live span: ``with tracer.span(...):`` emits an "X" event."""

    __slots__ = ("_tracer", "name", "cat", "args", "_start")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._start = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter_ns()
        tr = self._tracer
        tr._emit({"name": self.name, "cat": self.cat, "ph": "X",
                  "ts": (self._start - tr._t0) / 1e3,
                  "dur": (end - self._start) / 1e3,
                  "pid": tr.pid, "tid": tr.tid, "args": self.args})
        return False


class Tracer:
    """Ring-buffered span recorder with Chrome-trace JSON export.

    * ``span(name, **args)`` — a context manager; nesting is by lexical
      containment (the Chrome "X" complete-event model: a viewer stacks
      spans whose intervals nest on one track).
    * ``instant(name, **args)`` — a zero-duration marker ("i" event).
    * ``counter(name, value)`` — a "C" counter sample.
    * ``export(path)`` / ``to_dict()`` — the ``{"traceEvents": [...]}``
      JSON object perfetto loads directly.

    Timestamps are microseconds since the tracer's construction
    (``time.perf_counter_ns`` deltas — monotonic, never wall-time
    subject to NTP steps). Capacity bounds memory: the oldest events
    drop and ``dropped`` counts them, so a long run degrades to "the
    recent past" instead of OOM.
    """

    enabled = True

    def __init__(self, capacity: int = 1 << 16, pid: int = 0, tid: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1 (got {capacity})")
        self.capacity = int(capacity)
        self.pid = pid
        self.tid = tid
        self.events: Deque[Dict] = collections.deque(maxlen=self.capacity)
        self.dropped = 0
        self._t0 = time.perf_counter_ns()

    def __len__(self) -> int:
        return len(self.events)

    def _emit(self, ev: Dict) -> None:
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(ev)

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    # -- recording ------------------------------------------------------------

    def span(self, name: str, cat: str = "", **args) -> _Span:
        return _Span(self, name, cat or name.split("/", 1)[0], args)

    def instant(self, name: str, cat: str = "", **args) -> None:
        self._emit({"name": name, "cat": cat or name.split("/", 1)[0],
                    "ph": "i", "ts": self._now_us(), "s": "t",
                    "pid": self.pid, "tid": self.tid, "args": args})

    def counter(self, name: str, value: float) -> None:
        self._emit({"name": name, "ph": "C", "ts": self._now_us(),
                    "pid": self.pid, "tid": self.tid,
                    "args": {"value": float(value)}})

    # -- export ---------------------------------------------------------------

    def to_dict(self) -> Dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped": self.dropped,
                              "clock": "perf_counter_ns",
                              "capacity": self.capacity}}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path


def load_trace(path: str) -> Dict:
    """Load + structurally validate a Chrome-trace JSON file.

    The round-trip check the tests and the CI sample-trace step use:
    the object form with a ``traceEvents`` list whose entries carry the
    required ``name``/``ph``/``ts`` keys.
    """
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "traceEvents" not in data:
        raise ValueError(f"{path}: not a Chrome-trace JSON object "
                         "(missing 'traceEvents')")
    for i, ev in enumerate(data["traceEvents"]):
        for key in ("name", "ph", "ts"):
            if key not in ev:
                raise ValueError(f"{path}: traceEvents[{i}] missing {key!r}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"{path}: traceEvents[{i}] is a complete "
                             "event without 'dur'")
    return data


def span_tree(events: List[Dict]) -> List[Dict]:
    """Nest "X" events by interval containment (per pid/tid track).

    Returns the roots; each node gains a ``children`` list. Used by the
    round-trip tests to assert the recorded nesting is well-formed.
    """
    spans = [dict(e) for e in events if e.get("ph") == "X"]
    spans.sort(key=lambda e: (e.get("pid", 0), e.get("tid", 0),
                              e["ts"], -e["dur"]))
    roots: List[Dict] = []
    stack: List[Dict] = []
    for ev in spans:
        ev["children"] = []
        while stack and not (
                stack[-1].get("pid", 0) == ev.get("pid", 0)
                and stack[-1].get("tid", 0) == ev.get("tid", 0)
                and ev["ts"] + ev["dur"]
                <= stack[-1]["ts"] + stack[-1]["dur"] + 1e-6):
            stack.pop()
        (stack[-1]["children"] if stack else roots).append(ev)
        stack.append(ev)
    return roots
