"""Windowed-quantile estimation: the one estimator behind every tail.

Three subsystems grew the same estimator independently: the SLO
admission gate (``serve/slo.py``) controls on a windowed p99, the
router's hedging trigger (``serve/router.py``) fires past a windowed
p95, and ``DynamicBackup`` adapts its cutoff from a window of sorted
arrivals. This module is the extraction point: one stateless helper
(:func:`windowed_quantile` — the exact FIFO-window + ``np.percentile``
semantics both serving callers already had, so replays stay
bit-identical) and one stateful wrapper (:class:`WindowedQuantile` —
what :class:`repro.obs.metrics.Histogram` builds on).

Zero dependencies beyond numpy; no repro imports (``obs`` sits below
core/serve/train in the layer order).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def windowed_quantile(values: Sequence[float], quantile: float,
                      min_samples: int = 1,
                      default: float = 0.0) -> float:
    """Percentile of ``values`` — ``default`` until ``min_samples`` seen.

    The exact estimate both serving controllers computed inline:
    float64 ``np.percentile`` (linear interpolation) over the window,
    gated on a warmup count. Behavior-preserving by construction — the
    router replay tests pin this bit-for-bit.
    """
    if len(values) < min_samples:
        return default
    return float(np.percentile(np.asarray(values, np.float64), quantile))


class WindowedQuantile:
    """A bounded FIFO window of observations + its percentile estimate."""

    __slots__ = ("window", "quantile", "min_samples", "values")

    def __init__(self, window: int, quantile: float = 99.0,
                 min_samples: int = 1,
                 values: Optional[Sequence[float]] = None):
        if window < 1:
            raise ValueError(f"window must be >= 1 (got {window})")
        self.window = int(window)
        self.quantile = float(quantile)
        self.min_samples = int(min_samples)
        self.values: List[float] = [float(x) for x in (values or [])]

    def __len__(self) -> int:
        return len(self.values)

    @property
    def warm(self) -> bool:
        return len(self.values) >= self.min_samples

    def observe(self, x: float) -> None:
        self.values.append(float(x))
        if len(self.values) > self.window:
            self.values.pop(0)

    def estimate(self, default: float = 0.0,
                 quantile: Optional[float] = None) -> float:
        return windowed_quantile(
            self.values, self.quantile if quantile is None else quantile,
            self.min_samples, default)

    # -- checkpointable state -------------------------------------------------

    def state_dict(self) -> Dict:
        return {"values": [float(x) for x in self.values]}

    def load_state_dict(self, d: Dict) -> None:
        self.values = [float(x) for x in d["values"]][-self.window:]
