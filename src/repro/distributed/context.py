"""Ambient-mesh-aware sharding constraints for model code.

Model definitions stay mesh-agnostic: they call ``constrain_activations(x)``
at block boundaries, which is a no-op unless (a) a mesh with the expected
axes is ambient (jax.set_mesh) and (b) sequence-parallel activations were
enabled by the step builder. This is how Megatron-style SP lands without
threading mesh objects through every model: the saved residual stream
inside scanned+rematted blocks is sharded (batch->data, seq->model), which
divides the dominant activation-memory term by the model-axis size; GSPMD
inserts the all-gather before attention/matmuls and reduce-scatters after.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _get(name: str, default):
    return getattr(_state, name, default)


@contextlib.contextmanager
def sequence_parallel(enabled: bool = True):
    old = _get("sp", False)
    _state.sp = enabled
    try:
        yield
    finally:
        _state.sp = old


def sp_enabled() -> bool:
    return _get("sp", False)


@contextlib.contextmanager
def layer_param_constraints(fn):
    """Install a per-layer param constrainer (see sharding.layer_param_
    constrainer). Applied by scan bodies right after slicing the layer's
    params; the TRANSPOSE of a sharding constraint is the same constraint,
    so the per-layer weight GRADIENTS inside the backward while-loop
    inherit it too — without this, GSPMD materializes full replicated
    dW tensors per layer (observed: 1.7 GB f32 buffers on the 104B model)
    and all-reduces them instead of reduce-scattering."""
    old = _get("layer_fn", None)
    _state.layer_fn = fn
    try:
        yield
    finally:
        _state.layer_fn = old


def constrain_layer_params(tree):
    fn = _get("layer_fn", None)
    if fn is None:
        return tree
    return fn(tree)


@contextlib.contextmanager
def moe_data_sharding(enabled: bool = True):
    """Route MoE dispatch/combine through a shard_map over the data axes.

    Scatter/gather dispatch is opaque to GSPMD — without this it
    materializes the GLOBAL [E, C, d] dispatch buffer replicated on every
    device (observed: 10.7 GB f32 on qwen2-moe train_4k). Under shard_map
    each data shard dispatches only its local tokens with local capacity
    (per-group capacity, GShard semantics)."""
    old = _get("moe_shard", False)
    _state.moe_shard = enabled
    try:
        yield
    finally:
        _state.moe_shard = old


def moe_shard_axes():
    """Data axes to shard MoE dispatch over, or None when disabled/no mesh."""
    if not _get("moe_shard", False):
        return None
    mesh = _ambient_axes()
    if mesh is None:
        return None
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes or None


def _ambient_axes():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.get_concrete_mesh() or mesh_lib.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return m
    except Exception:  # noqa: BLE001 — constraint is best-effort sugar
        return None


def constrain_dims(x, kinds):
    """Best-effort constraint by dimension kind: 'batch' -> data axes,
    'heads' -> 'model', None -> unconstrained. No-op without an ambient
    mesh or when nothing divides. Used inside the chunked attention core,
    where reshape/transpose chains otherwise drop GSPMD's head sharding
    and the online-softmax accumulators replicate (observed 3.2 GB
    [nq,B,H,qc,hd] f32 buffers on command-r prefill)."""
    mesh = _ambient_axes()
    if mesh is None:
        return x
    names = dict(zip(mesh.axis_names, mesh.axis_sizes
                     if hasattr(mesh, "axis_sizes") else mesh.devices.shape))
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= names[a]
    model = names.get("model", 1)
    entries = []
    nontrivial = False
    for dim, kind in zip(x.shape, kinds):
        if kind == "batch" and dp_axes and dim % dp == 0 and dim >= dp:
            entries.append(dp_axes if len(dp_axes) > 1 else dp_axes[0])
            nontrivial = True
        elif kind == "heads" and "model" in names and dim % model == 0 \
                and dim >= model:
            entries.append("model")
            nontrivial = True
        else:
            entries.append(None)
    if not nontrivial:
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


def constrain_activations(x):
    """[B, S, d] residual stream -> (batch: data axes, seq: 'model')."""
    if not sp_enabled() or x.ndim != 3:
        return x
    mesh = _ambient_axes()
    if mesh is None:
        return x
    names = dict(zip(mesh.axis_names, mesh.axis_sizes
                     if hasattr(mesh, "axis_sizes") else mesh.devices.shape))
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= names[a]
    model = names.get("model", 1)
    batch_entry = (dp_axes if len(dp_axes) > 1 else dp_axes[0]) \
        if (dp_axes and x.shape[0] % dp == 0 and x.shape[0] >= dp) else None
    seq_entry = "model" if ("model" in names and x.shape[1] % model == 0
                            and x.shape[1] >= model) else None
    if batch_entry is None and seq_entry is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(batch_entry, seq_entry, None))
