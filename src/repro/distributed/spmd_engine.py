"""SPMD execution engine: coordination strategies over a real device mesh.

Every path built in PRs 1–3 executes the paper's W workers as a *loop
index* on one device: the global batch is one array, per-worker gradients
are either implicit (the mask-weighted loss trick) or a stacked
``[W, ...]`` pytree. This module is the execution substrate the paper
actually describes — N workers computing gradients **in parallel on
distinct devices**:

* the W coordination workers are laid out over the mesh's ``'data'``
  axis (``W % mesh_data == 0``; each shard owns ``W / mesh_data``
  contiguous workers and only *their* rows of the global batch);
* each shard computes its local workers' mean gradients sequentially
  (``lax.map`` — one worker's activation memory at a time, exactly the
  per-machine footprint of the paper's setup);
* the paper's Alg. 4 line 7 ``(1/N) * sum_{selected} G_w`` is realized
  as a **collective**: the in-shard masked reduce is the
  ``kernels.backup_reduce`` Pallas kernel (or the jnp reference) over
  the local ``[W_local, P]`` stack, followed by one ``psum`` over
  ``'data'`` — at no point does a stacked ``[W, ...]`` gradient tree
  exist on any single device;
* the optimizer + EMA apply to the (replicated) aggregated gradient
  outside the shard_map, so checkpoints keep the exact on-disk format
  of the simulated backend.

The mask itself stays host-planned (the ``StragglerSimulator`` /
``CoordinationStrategy.select`` contract is unchanged — masks are *data*
to the engine), so the mesh run is comparable step-for-step with the
single-device simulated run: parity is allclose, not bit-exact, because
the sim backend differentiates the mask-weighted global loss while the
engine sums explicit per-worker gradients (the same value in different
floating-point association).

**Tensor parallelism over the ``'model'`` axis**: with ``mesh_model > 1``
the engine shards model parameters, optimizer state and EMA over the
mesh's second axis (PartitionSpecs from ``distributed.sharding.tp_plan``
/ ``tp_param_specs`` / ``tp_state_specs``), and each worker's gradient
is computed **tensor-parallel inside its 'data' shard**: the model runs
with a per-shard config (heads / hidden width divided by ``mesh_model``)
and the Megatron f/g collectives of ``repro.distributed.tp`` supply the
explicit psums over ``'model'`` at the contracted dims (attention out,
FFN down-projection, vocab-sharded embedding/cross-entropy). The masked
aggregation then runs ON the sharded trees: each ``(data, model)`` shard
kernel-reduces its local ``[W_local, P_local]`` flatten and one psum
over ``'data'`` completes Alg. 4 line 7 — params, opt state, gradients
and EMA never leave their shard during a step (gather/scatter happens
only at checkpoint save/restore, which keeps checkpoints interchangeable
with replicated and simulated runs). Groups that cannot shard (config
indivisible by ``mesh_model``, biased row-parallel layers, non-
transformer families) stay replicated per the plan; when nothing shards
the axis is carried exactly as in the pre-TP engine.

Chunking composes: ``build_spmd_chunk_step`` wraps the step in the same
``lax.scan`` as the single-device chunked loop — the scan carries the
*sharded* param/opt/EMA trees, so one dispatch covers K steps across the
whole mesh. See docs/spmd.md.
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ema as ema_lib
from repro.distributed import sharding as sharding_lib
from repro.distributed import tp
from repro.kernels.bucketed_reduce import reduce_then_psum
from repro.launch.mesh import make_host_mesh
from repro.optim import optimizers as opt_lib

WORKER_AXIS = "data"
MODEL_AXIS = "model"


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: jax.shard_map (>= 0.6, check_vma)
    where it exists, else jax.experimental.shard_map (check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# Mesh construction / layout validation
# ---------------------------------------------------------------------------


def build_mesh(exec_cfg) -> Mesh:
    """The engine's ('data', 'model') worker mesh from an ExecutionConfig."""
    need = exec_cfg.num_devices
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"execution backend 'spmd' needs mesh_data*mesh_model = {need} "
            f"devices but only {have} present; on CPU hosts force devices "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return make_host_mesh(exec_cfg.mesh_data, exec_cfg.mesh_model)


def validate_layout(num_workers: int, global_batch: int,
                    mesh_data: int) -> int:
    """Checks W/B divisibility over the data axis; returns W_local."""
    if mesh_data < 1:
        raise ValueError(f"mesh_data must be >= 1 (got {mesh_data})")
    if num_workers % mesh_data:
        raise ValueError(
            f"spmd engine maps workers onto the '{WORKER_AXIS}' axis: "
            f"total_workers ({num_workers}) must be divisible by "
            f"mesh_data ({mesh_data})")
    if global_batch % num_workers:
        raise ValueError(
            f"global_batch ({global_batch}) must be divisible by "
            f"total_workers ({num_workers})")
    return num_workers // mesh_data


def _auto_interpret(interpret: Optional[bool]) -> bool:
    """Pallas runs natively on TPU only; anywhere else use interpret mode."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _auto_use_kernel(use_kernel: Optional[bool]) -> bool:
    """Default reduce implementation: the Pallas kernel where it compiles
    natively (TPU), the jnp dot elsewhere — interpret-mode Pallas is pure
    overhead on CPU/GPU (measured in BENCH_spmd; docs/spmd.md)."""
    if use_kernel is not None:
        return use_kernel
    return jax.default_backend() == "tpu"


def validate_grad_batch(grad_batch: int, w_local: int) -> int:
    """Resolve ``ExecutionConfig.grad_batch`` against the local worker
    count; returns the effective batch size.

    ``0`` (the default) batches ALL local workers through one ``vmap`` —
    the fast path whenever activation memory allows, since every worker's
    forward/backward fuses into one program with no inner loop. ``1``
    recovers the sequential ``lax.map`` (one worker's activations live at
    a time — the per-machine footprint of the paper's setup). Any other
    value microbatches: groups of ``grad_batch`` workers are vmapped and
    the groups run sequentially, so it must divide ``W_local``.
    """
    if grad_batch < 0:
        raise ValueError(
            f"grad_batch: expected a non-negative worker-batch size, got "
            f"{grad_batch} (0 = vmap all local workers, 1 = sequential "
            f"lax.map, k = microbatches of k workers)")
    if grad_batch and w_local % grad_batch:
        divisors = [d for d in range(1, w_local + 1) if w_local % d == 0]
        raise ValueError(
            f"grad_batch: {grad_batch} does not divide the per-shard "
            f"worker count W_local={w_local} (total_workers / mesh_data); "
            f"valid values here: 0 (vmap all) or one of {divisors}")
    return grad_batch or w_local


# ---------------------------------------------------------------------------
# Stacked-gradient flatten/unflatten (the kernel's [W_local, P] view)
# ---------------------------------------------------------------------------


def flatten_stacked(tree: Any) -> Tuple[jnp.ndarray, Tuple]:
    """[W, ...] pytree -> ([W, P] f32, spec) with P = total param count."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(l.shape[1:] for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    flat = jnp.concatenate(
        [l.reshape((l.shape[0], -1)).astype(jnp.float32) for l in leaves],
        axis=1)
    return flat, (treedef, shapes, dtypes)


def unflatten_vector(vec: jnp.ndarray, spec: Tuple) -> Any:
    """[P] f32 -> pytree with the original shapes/dtypes."""
    treedef, shapes, dtypes = spec
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    offsets = np.cumsum([0] + sizes)
    leaves = [
        vec[offsets[i]:offsets[i + 1]].reshape(shapes[i]).astype(dtypes[i])
        for i in range(len(shapes))]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Per-worker loss (paper semantics: each worker's own mini-batch mean)
# ---------------------------------------------------------------------------


def make_worker_loss(model) -> Callable:
    """loss(params, worker_batch) -> (scalar, (mean_loss, aux)).

    Mirrors ``train_step.make_loss_fn``'s per-example loss (token-validity
    masking, vlm prefix padding) but at single-worker granularity: the
    worker's gradient is the gradient of ITS mini-batch mean — including
    its own aux loss, as a real worker machine would compute it. (The sim
    backend instead adds one global-batch aux term; the two agree
    whenever aux == 0, i.e. all non-MoE models.)
    """

    def loss_fn(params, batch):
        per_tok, aux = model.per_token_loss(params, batch)
        labels = batch["labels"]
        if per_tok.shape[1] != labels.shape[1]:       # vlm prefix positions
            pad = per_tok.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels],
                1)
        valid = (labels >= 0).astype(jnp.float32)
        per_ex = (jnp.sum(per_tok * valid, axis=-1)
                  / jnp.maximum(jnp.sum(valid, axis=-1), 1.0))
        mean_loss = jnp.mean(per_ex)
        return mean_loss + aux, (mean_loss, aux)

    return loss_fn


# ---------------------------------------------------------------------------
# The engine step
# ---------------------------------------------------------------------------


def resolve_tp(model_cfg, mesh: Mesh) -> sharding_lib.TPPlan:
    """The TP plan for a mesh ('model' axis size) + model config pair.

    Warns when ``mesh_model > 1`` was requested but no parameter group can
    shard (indivisible config, biased layers, non-transformer family, or
    a config-less model override) — the axis is then carried (replicated),
    the pre-TP engine semantics."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    mesh_model = names.get(MODEL_AXIS, 1)
    plan = sharding_lib.tp_plan(model_cfg, mesh_model)
    if mesh_model > 1 and not plan.any:
        warnings.warn(
            f"mesh_model={mesh_model} but no parameter group is shardable "
            f"for this model (see sharding.tp_plan: divisibility, biases, "
            f"family); the '{MODEL_AXIS}' axis will be carried (replicated)",
            stacklevel=2)
    return plan


def _params_template(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def build_spmd_step(model, optimizer: opt_lib.Optimizer, mesh: Mesh, *,
                    num_workers: int, n_aggregate: int,
                    ema_decay: float = 0.0, clip_norm: float = 0.0,
                    use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None,
                    block: int = 4096, grad_batch: int = 0,
                    bucket_size: int = 0, model_cfg=None) -> Callable:
    """Mesh twin of ``train_step.build_train_step`` — same signature:

        step(params, opt_state, ema, step, batch, mask)
            -> (params, opt_state, ema, metrics)

    ``batch`` rows are worker-contiguous (the data-pipeline layout), so
    sharding axis 0 over ``'data'`` gives each shard exactly its local
    workers' rows; ``mask`` is the host-planned [W] selection, sharded to
    [W_local] per shard. Per-worker gradients are BATCHED per
    ``grad_batch`` (0 = one ``vmap`` over all local workers — the fast
    path; 1 = the sequential ``lax.map``, one worker's activations at a
    time; k = microbatches of k vmapped workers run sequentially).
    Aggregation is the fused bucketed reduce-then-psum
    (``kernels.bucketed_reduce``): the in-shard masked reduce (Pallas
    ``backup_reduce`` or the jnp dot, per ``use_kernel``) runs per
    ``bucket_size`` lanes and each bucket's ``psum`` over ``'data'`` is
    issued as soon as that bucket reduces, with the step's monitoring
    scalars packed into the last bucket — one collective per bucket
    covers gradient + metrics. Optimizer/EMA run outside the shard_map.

    With ``model_cfg`` given and a non-trivial TP plan (mesh 'model' axis
    > 1, shardable groups), params/opt/EMA enter SHARDED over 'model':
    the shard_map body sees local parameter slices, the per-worker loss
    runs the per-shard model (heads / d_ff divided) under the
    ``repro.distributed.tp`` context that inserts the f/g psums, and the
    aggregated gradient leaves the shard_map still sharded — the
    optimizer and EMA then apply shard-wise under GSPMD (elementwise ops
    preserve the sharding), so no resharding round-trip exists anywhere
    in the step.
    """
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    mesh_data = names[WORKER_AXIS]
    if num_workers % mesh_data:
        raise ValueError(
            f"total_workers ({num_workers}) must be divisible by the "
            f"'{WORKER_AXIS}' axis size ({mesh_data})")
    w_local = num_workers // mesh_data
    gb = validate_grad_batch(grad_batch, w_local)
    interp = _auto_interpret(interpret)
    use_kernel = _auto_use_kernel(use_kernel)
    plan = resolve_tp(model_cfg, mesh)
    if plan.any:
        from repro.models import get_model
        local_model = get_model(sharding_lib.tp_local_model_cfg(model_cfg, plan))
        worker_loss = make_worker_loss(local_model)
        param_specs = sharding_lib.tp_param_specs(plan, _params_template(model))
        tp_ctx = tp.TPContext(MODEL_AXIS, plan.attn, plan.ffn, plan.vocab)
    else:
        worker_loss = make_worker_loss(model)
        param_specs = P()                       # replicated (pytree prefix)
        tp_ctx = None

    def shard_grads(batch, mask, params):
        # batch: local rows [b_local, ...]; mask: [W_local]; params: full
        # when replicated, the local 'model'-axis slices under a TP plan
        def reshape(x):
            return x.reshape((w_local, x.shape[0] // w_local) + x.shape[1:])

        shards = jax.tree_util.tree_map(reshape, batch)

        def one_worker(worker_batch):
            (_, (mean_loss, aux)), g = jax.value_and_grad(
                worker_loss, has_aux=True)(params, worker_batch)
            return g, mean_loss, aux

        # per-worker gradients, batched per grad_batch: the full vmap is
        # one fused program with no inner loop (the fast path); lax.map
        # keeps one worker's activations live at a time — the per-machine
        # memory footprint of the paper's setup; k-sized microbatches
        # interpolate. The tp context is entered here (inside the traced
        # body) so the f/g psum hooks fire exactly for engine-built
        # computations.
        with tp.tensor_parallel(tp_ctx) if tp_ctx else contextlib.nullcontext():
            if gb == w_local:
                grads, losses, auxes = jax.vmap(one_worker)(shards)
            elif gb == 1:
                grads, losses, auxes = jax.lax.map(one_worker, shards)
            else:
                groups = jax.tree_util.tree_map(
                    lambda x: x.reshape((w_local // gb, gb) + x.shape[1:]),
                    shards)
                grads, losses, auxes = jax.lax.map(
                    lambda g: jax.vmap(one_worker)(g), groups)
                grads, losses, auxes = jax.tree_util.tree_map(
                    lambda x: x.reshape((w_local,) + x.shape[2:]),
                    (grads, losses, auxes))
        mf = mask.astype(jnp.float32)
        # fused bucketed reduce-then-psum (kernels.bucketed_reduce): the
        # in-shard masked reduce runs per bucket and each bucket's psum
        # over 'data' is issued immediately, with the two monitoring
        # scalars riding the last bucket — ceil(P/bucket) collectives
        # (ONE by default) cover Alg. 4 line 7 plus the metrics. Losses
        # are replicated over 'model' (the CE ends in psums), so only
        # the 'data' reduction is collective.
        flat, spec = flatten_stacked(grads)         # [W_local, P_local] f32
        tail = jnp.stack([jnp.sum(losses * mf), jnp.sum(auxes)])
        red, tail = reduce_then_psum(
            flat, mask, n_aggregate, axis_name=WORKER_AXIS,
            bucket=bucket_size, tail=tail, use_kernel=use_kernel,
            interpret=interp, block=block)
        agg = unflatten_vector(red, spec)
        sel = tail[0] / n_aggregate
        aux = tail[1] / num_workers
        return agg, sel, aux

    mapped = _shard_map(
        shard_grads, mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), param_specs),
        out_specs=(param_specs, P(), P()))

    def step_fn(params, opt_state, ema_state, step, batch, mask):
        grads, sel, aux = mapped(batch, mask, params)
        frac = jnp.sum(mask.astype(jnp.float32)) / n_aggregate
        metrics = {"loss": sel / jnp.maximum(frac, 1e-6), "aux_loss": aux}
        if clip_norm > 0:
            # global_norm sums over all leaves; on sharded trees GSPMD
            # lowers the per-leaf reductions to one small all-reduce
            grads, gnorm = opt_lib.clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        new_params, new_opt, stats = optimizer.apply(params, grads,
                                                     opt_state, step)
        metrics.update(stats)
        if ema_decay > 0:
            ema_state = ema_lib.update(ema_state, new_params, ema_decay)
        return new_params, new_opt, ema_state, metrics

    return step_fn


def build_spmd_chunk_step(model, optimizer: opt_lib.Optimizer, mesh: Mesh,
                          **step_kwargs) -> Callable:
    """Mesh twin of the host-mask ``build_chunk_step``: one ``lax.scan``
    dispatch covers K steps across the whole mesh.

        chunk(params, opt, ema, step0, batches [K, B, ...], masks [K, W])
            -> (params, opt, ema, metrics {k: [K]})

    The scan body is the unmodified ``build_spmd_step`` function, so
    chunking never changes the mesh semantics — only the dispatch count.
    """
    step_fn = build_spmd_step(model, optimizer, mesh, **step_kwargs)

    def scan_steps(params, opt_state, ema_state, step0, batches, masks):
        def body(carry, xs):
            p, o, e, step = carry
            batch, mask = xs
            p, o, e, m = step_fn(p, o, e, step, batch, mask)
            return (p, o, e, step + 1), m

        (p, o, e, _), ms = jax.lax.scan(
            body, (params, opt_state, ema_state, step0), (batches, masks))
        return p, o, e, ms

    return scan_steps


# ---------------------------------------------------------------------------
# Jitted entry points (what the Trainer installs)
# ---------------------------------------------------------------------------


def _replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def state_shardings(model, optimizer, mesh: Mesh, *, ema_decay: float = 0.0,
                    model_cfg=None) -> Tuple[Any, Any, Any]:
    """(params, opt_state, ema) NamedSharding trees for the engine's jit.

    Replicated trees without a TP plan (the pre-TP engine contract);
    under a plan, params shard per ``sharding.tp_param_specs`` and the
    optimizer/EMA state — whatever its tree structure — inherits the
    matching parameter's spec by path suffix (``sharding.tp_state_specs``).
    (The plan/templates are also derived inside ``build_spmd_step``; both
    are cheap eval_shape/spec walks that run once per Trainer build.)
    """
    plan = sharding_lib.tp_plan(
        model_cfg,
        dict(zip(mesh.axis_names, mesh.devices.shape)).get(MODEL_AXIS, 1))
    rep = _replicated(mesh)
    if not plan.any:
        return rep, rep, rep
    params_t = _params_template(model)
    opt_t = jax.eval_shape(optimizer.init, params_t)

    def named(spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    psh = named(sharding_lib.tp_param_specs(plan, params_t))
    osh = named(sharding_lib.tp_state_specs(plan, opt_t))
    if ema_decay > 0:
        ema_t = jax.eval_shape(ema_lib.init, params_t)
        esh = named(sharding_lib.tp_state_specs(plan, ema_t))
    else:
        esh = rep                               # ema arg is None
    return psh, osh, esh



def _traced(fn, tracer) -> Callable:
    """Bracket a jitted mesh step with spmd/dispatch + collective-wait
    spans. Only installed when a live tracer is passed: the fence
    (``block_until_ready``) serializes dispatch against device work, so
    the untraced path must keep the bare async-dispatch callable."""
    def call(*args):
        with tracer.span("spmd/dispatch"):
            out = fn(*args)
        with tracer.span("spmd/collective_wait"):
            jax.block_until_ready(out)
        return out
    return call


def make_train_step(model, optimizer, mesh: Mesh, *, tracer=None,
                    **step_kwargs) -> Callable:
    """Jitted per-step engine, drop-in for the Trainer's ``train_step``:
    step/mask replicated, batch rows sharded over 'data', and params/
    opt/ema replicated — or sharded over 'model' under a TP plan. The
    state out_shardings are pinned to the in_shardings, so the sharded
    carry round-trips the Trainer loop without resharding."""
    psh, osh, esh = state_shardings(
        model, optimizer, mesh,
        ema_decay=step_kwargs.get("ema_decay", 0.0),
        model_cfg=step_kwargs.get("model_cfg"))
    rep = _replicated(mesh)
    bsh = NamedSharding(mesh, P(WORKER_AXIS))
    fn = jax.jit(build_spmd_step(model, optimizer, mesh, **step_kwargs),
                 in_shardings=(psh, osh, esh, rep, bsh, rep),
                 out_shardings=(psh, osh, esh, rep),
                 donate_argnums=(0, 1, 2))
    return _traced(fn, tracer) if tracer is not None and tracer.enabled \
        else fn


def make_chunk_step(model, optimizer, mesh: Mesh, *, tracer=None,
                    **step_kwargs) -> Callable:
    """Jitted K-step engine, drop-in for the Trainer's ``chunk_step``:
    stacked batches [K, B, ...] shard axis 1 (the batch rows) over 'data';
    the scan carries the (possibly 'model'-sharded) state trees."""
    psh, osh, esh = state_shardings(
        model, optimizer, mesh,
        ema_decay=step_kwargs.get("ema_decay", 0.0),
        model_cfg=step_kwargs.get("model_cfg"))
    rep = _replicated(mesh)
    bsh = NamedSharding(mesh, P(None, WORKER_AXIS))
    fn = jax.jit(
        build_spmd_chunk_step(model, optimizer, mesh, **step_kwargs),
        in_shardings=(psh, osh, esh, rep, bsh, rep),
        out_shardings=(psh, osh, esh, rep),
        donate_argnums=(0, 1, 2))
    return _traced(fn, tracer) if tracer is not None and tracer.enabled \
        else fn
