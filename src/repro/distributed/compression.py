"""Gradient compression for the wire (paper §6 future work: reduce comms).

Two schemes:
  * bf16        — stateless round-to-bf16 (what the SPMD path gets for free
                  when grads are bf16; halves collective bytes vs f32).
  * int8_ef     — per-tensor-scaled int8 quantization with ERROR FEEDBACK
                  (Seide et al. 2014 / 1-bit SGD lineage): the quantization
                  residual is carried to the next step so the compression
                  bias telescopes away.

Compressed gradients are a dict-of-trees {"q": int8 tree, "scale": scalar
tree} so they remain ordinary pytrees. Used by the simulator paths (where
the wire is explicit); quantization error bounds and the error-feedback
telescoping property are tested in tests/test_compression.py.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def compress_bf16(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), tree)


def decompress_bf16(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), tree)


def _quant_one(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_one(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_int8(tree: Any) -> Dict[str, Any]:
    q = jax.tree_util.tree_map(lambda g: _quant_one(g)[0], tree)
    scale = jax.tree_util.tree_map(lambda g: _quant_one(g)[1], tree)
    return {"q": q, "scale": scale}


def decompress_int8(c: Dict[str, Any]) -> Any:
    return jax.tree_util.tree_map(_dequant_one, c["q"], c["scale"])


def init_error_feedback(params_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params_like)


def compress_with_error_feedback(grads: Any, errors: Any
                                 ) -> Tuple[Dict[str, Any], Any]:
    """q = Q(g + e);  e' = (g + e) - deq(q). Returns (compressed, new_errors)."""
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, errors)
    c = compress_int8(corrected)
    new_errors = jax.tree_util.tree_map(
        lambda x, q, s: x - _dequant_one(q, s), corrected, c["q"], c["scale"])
    return c, new_errors


def compressed_bytes(tree: Any, scheme: str) -> int:
    """Wire bytes for a gradient pytree under each scheme (for the roofline)."""
    leaves = jax.tree_util.tree_leaves(tree)
    n = sum(int(x.size) for x in leaves)
    if scheme == "none":
        return 4 * n
    if scheme == "bf16":
        return 2 * n
    if scheme == "int8_ef":
        return n + 4 * len(leaves)
    raise ValueError(scheme)
