"""Manual tensor parallelism: the Megatron f/g collectives + ambient plan.

The SPMD execution engine (``repro.distributed.spmd_engine``) runs the
model *inside* a fully-manual ``shard_map`` over the ``('data','model')``
mesh, so GSPMD never sees the model axis — every cross-shard reduction
must be written explicitly. This module supplies the two collective
primitives and the trace-time context that tells model code which
parameter groups are actually sharded.

The discipline (Megatron-LM's f/g operators, Shoeybi et al. 2019):

* ``psum_fwd`` — psum on the forward pass, **identity** on the backward
  pass. Placed after a row-parallel matmul (``wo``, ``w_down``, the
  vocab-sharded embedding lookup, the cross-entropy partial sums), where
  each shard holds a partial sum and the *cotangent* of the summed
  result is replicated.
* ``psum_bwd`` — identity on the forward pass, **psum** on the backward
  pass. Placed on a replicated activation entering a column-parallel
  matmul (``wq/wk/wv``, ``w_up/w_gate``, the LM head), where the forward
  value is already replicated but each shard only produces its local
  slice of the cotangent.

Together they maintain the invariant that *the cotangent of every
replicated activation is fully assembled on every shard*: gradients of
sharded leaves come out exact-and-local, gradients of replicated leaves
(norm scales, biases) come out exact-and-replicated — no post-hoc
correction psums, no double counting. (A plain ``lax.psum`` cannot be
used: under ``shard_map(check_rep=False)`` its transpose is ``psum``,
which over-counts replicated cotangents by the axis size.)

Model code opts in through three hooks — all identity unless a
:class:`TPContext` is ambient *at trace time* (the engine enters it
inside the traced step, so only engine-built computations see it):

    ``col_in(x, group)``   -> psum_bwd when ``group`` is sharded
    ``row_out(x, group)``  -> psum_fwd when ``group`` is sharded
    ``sharded_embed`` / ``sharded_cross_entropy``  (vocab group)

Groups are ``'attn'`` (head-sharded projections), ``'ffn'`` (hidden-dim
sharded MLP), ``'vocab'`` (embedding/LM-head rows). Which groups shard —
and the matching PartitionSpecs — is decided by
``repro.distributed.sharding.tp_plan`` (divisibility + group-consistency
rules).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# f/g collectives
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def psum_fwd(x, axis: str):
    """psum on forward, identity on backward (Megatron's ``f`` merge)."""
    return jax.lax.psum(x, axis)


def _psum_fwd_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _psum_fwd_bwd(axis, _, ct):
    return (ct,)


psum_fwd.defvjp(_psum_fwd_fwd, _psum_fwd_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def psum_bwd(x, axis: str):
    """identity on forward, psum on backward (Megatron's ``g`` scatter)."""
    return x


def _psum_bwd_fwd(x, axis):
    return x, None


def _psum_bwd_bwd(axis, _, ct):
    return (jax.lax.psum(ct, axis),)


psum_bwd.defvjp(_psum_bwd_fwd, _psum_bwd_bwd)


# ---------------------------------------------------------------------------
# The ambient plan (trace-time, thread-local)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPContext:
    """Which parameter groups are sharded over which manual mesh axis."""

    axis: str = "model"
    attn: bool = False
    ffn: bool = False
    vocab: bool = False


_state = threading.local()


def current() -> Optional[TPContext]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def tensor_parallel(ctx: Optional[TPContext]):
    """Install ``ctx`` for the duration of a trace (None = no-op)."""
    old = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        yield
    finally:
        _state.ctx = old


def _group_axis(group: str) -> Optional[str]:
    ctx = current()
    if ctx is not None and getattr(ctx, group):
        return ctx.axis
    return None


def col_in(x, group: str):
    """Replicated activation entering a column-parallel matmul."""
    axis = _group_axis(group)
    return x if axis is None else psum_bwd(x, axis)


def row_out(x, group: str):
    """Partial sum leaving a row-parallel matmul."""
    axis = _group_axis(group)
    return x if axis is None else psum_fwd(x, axis)


def shared_param(tree, group: str):
    """A replicated parameter (sub)tree consumed INSIDE a sharded region
    (e.g. the per-head-dim qk-norm scales applied to head-sharded q/k):
    identity forward, psum backward per leaf, so the per-shard partial
    cotangents assemble into the full — and replicated — gradient."""
    axis = _group_axis(group)
    if axis is None:
        return tree
    return jax.tree_util.tree_map(lambda x: psum_bwd(x, axis), tree)


# ---------------------------------------------------------------------------
# Vocab-sharded embedding + cross-entropy
# ---------------------------------------------------------------------------


def vocab_active() -> Optional[str]:
    """The manual axis name when the vocab group is sharded, else None."""
    return _group_axis("vocab")


def sharded_embed(table: jnp.ndarray, ids: jnp.ndarray,
                  axis: str) -> jnp.ndarray:
    """Lookup into a vocab-sharded ``[V_local, d]`` table.

    Each shard gathers the rows it owns (out-of-slice ids contribute
    zeros) and one psum assembles the replicated embedding — the f merge,
    so the backward scatter stays local to the owning shard.
    """
    v_local = table.shape[0]
    start = jax.lax.axis_index(axis) * v_local
    local = ids - start
    ok = (local >= 0) & (local < v_local)
    rows = jnp.take(table, jnp.clip(local, 0, v_local - 1), axis=0)
    rows = jnp.where(ok[..., None], rows, jnp.zeros((), rows.dtype))
    return psum_fwd(rows, axis)


def sharded_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          valid_vocab: Optional[int], axis: str) -> jnp.ndarray:
    """``lse - label_logit`` over vocab-sharded logits ``[..., V_local]``.

    The max is a (non-differentiated) pmax, the sum-exp and the label
    gather are per-shard partials merged with ``psum_fwd`` — the exact
    value of the replicated cross-entropy without ever materializing the
    full ``[..., V]`` logits on one shard.
    """
    logits = logits.astype(jnp.float32)
    v_local = logits.shape[-1]
    start = jax.lax.axis_index(axis) * v_local
    if valid_vocab is not None:
        cols = start + jnp.arange(v_local)
        logits = jnp.where(cols >= valid_vocab, -1e30, logits)
    m = jax.lax.pmax(
        jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True)), axis)
    lse = jnp.log(psum_fwd(jnp.sum(jnp.exp(logits - m), axis=-1), axis)) \
        + m[..., 0]
    local = labels - start
    ok = (local >= 0) & (local < v_local)
    lab = jnp.take_along_axis(
        logits, jnp.clip(local, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    label_logit = psum_fwd(jnp.where(ok, lab, 0.0), axis)
    return lse - label_logit
