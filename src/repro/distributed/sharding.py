"""Sharding rules: parameter/optimizer/input PartitionSpecs per mesh.

Rules are path-based with divisibility guards — a dimension is only
sharded when it divides evenly by the mesh axis (e.g. gemma3's 4 heads
stay replicated on a 16-way model axis while its d_ff/vocab shard).

Conventions (single pod mesh ('data','model'); multi-pod adds 'pod'):
  * batch dims of activations/inputs -> ('pod','data')
  * TP: attention head dims, FFN hidden dim, vocab -> 'model'
  * MoE 'ep': expert dim -> 'model'; 'tp': expert d_ff -> 'model'
  * ZeRO-1: optimizer state additionally shards the first replicated,
    divisible dimension over ('pod','data')
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def param_spec(path: str, shape: Tuple[int, ...], cfg, model_size: int) -> P:
    """PartitionSpec for one parameter tensor (leading dim may be layers)."""
    nd = len(shape)
    none = (None,) * nd

    def at(axis_idx: int, name: str) -> P:
        if not _div(shape[axis_idx], model_size):
            return P(*none)
        spec = list(none)
        spec[axis_idx] = name
        return P(*spec)

    # embeddings: [V, d] shard vocab; output head [d, V] shard vocab
    if path.endswith("embed/embedding"):
        return at(0, "model")
    if re.search(r"(lm_head|head)/w$", path):
        return at(nd - 1, "model")
    # MoE experts: [L, E, d_in, d_out]
    if re.search(r"moe/(w_gate|w_up|w_down)/w$", path):
        if cfg.moe.partition_mode == "ep":
            return at(nd - 3, "model")          # expert dim
        if path.endswith("w_down/w"):
            return at(nd - 2, "model")          # contract dim = expert d_ff
        return at(nd - 1, "model")
    if path.endswith("router/w"):
        return P(*none)
    # attention projections
    if re.search(r"(attn|xattn)/(wq|wk|wv)/w$", path) or \
       re.search(r"(wkv_b|wq_b|wq)/w$", path):
        return at(nd - 1, "model")
    if re.search(r"(attn|xattn)/wo/w$", path) or path.endswith("ssd_out/w"):
        return at(nd - 2, "model")
    # dense FFN
    if re.search(r"(mlp|shared|ffn)/(w_up|w_gate|wk)/w$", path):
        return at(nd - 1, "model")
    if re.search(r"(mlp|shared|ffn)/(w_down|wv)/w$", path):
        return at(nd - 2, "model")
    # rwkv time-mix projections [L, d, d]
    if re.search(r"att/(wr|wk|wv|wg)/w$", path):
        return at(nd - 1, "model")
    if re.search(r"att/wo/w$", path):
        return at(nd - 2, "model")
    # ssd projections [L, d, H*P]
    if re.search(r"ssd/(wx|wb|wc)/w$", path):
        return at(nd - 1, "model")
    # everything else (norms, biases, scalars, router, conv) replicated
    return P(*none)


def zero1_spec(spec: P, shape: Tuple[int, ...], dp_axes: Tuple[str, ...],
               dp_size: int, *, prefer_inner: bool = False) -> P:
    """Additionally shard over the data axes (ZeRO-1 opt state / FSDP params
    / ZeRO-2 gradient accumulators).

    Picks the first replicated, divisible dimension. ``prefer_inner`` skips
    the leading (layer-stack) dim when a later dim qualifies, so FSDP
    all-gathers stream per layer instead of gathering the whole stack.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    order = list(range(len(shape)))
    if prefer_inner and len(shape) > 1:
        order = order[1:] + [0]
    for i in order:
        if entries[i] is None and _div(shape[i], dp_size) and shape[i] >= dp_size:
            entries[i] = dp
            return P(*entries)
    return spec


def param_shardings(cfg, mesh: Mesh, shape_tree: Any, *,
                    fsdp: bool = False) -> Any:
    """Pytree of NamedShardings matching a model's param shapes.

    fsdp=True additionally shards every parameter over the data axes
    (ZeRO-3): XLA all-gathers each layer's weights at use inside the
    scanned stack and the memory per device drops by the DP size.
    """
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = names.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([names[a] for a in dp_axes])) if dp_axes else 1

    def leaf(path, x):
        spec = param_spec(_path_str(path), x.shape, cfg, model_size)
        if fsdp:
            spec = zero1_spec(spec, x.shape, dp_axes, dp_size, prefer_inner=True)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, shape_tree)


def layer_param_constrainer(cfg, mesh: Mesh, *, fsdp: bool = False):
    """Returns fn(layer_param_tree) applying with_sharding_constraint to
    every leaf using the same path rules as param_shardings (paths inside a
    layer match because the rules are suffix-based). Installed via
    distributed.context.layer_param_constraints inside scan bodies."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = names.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([names[a] for a in dp_axes])) if dp_axes else 1

    def constrain(tree):
        def leaf(path, x):
            spec = param_spec(_path_str(path), x.shape, cfg, model_size)
            if fsdp:
                spec = zero1_spec(spec, x.shape, dp_axes, dp_size,
                                  prefer_inner=True)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

        return jax.tree_util.tree_map_with_path(leaf, tree)

    return constrain


def grad_shardings(cfg, mesh: Mesh, shape_tree: Any) -> Any:
    """ZeRO-2 gradient(-accumulator) shardings: param spec + data axes.

    Constraining per-microbatch grads to this turns the DP all-reduce into
    a reduce-scatter and keeps the accumulator sharded."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = names.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([names[a] for a in dp_axes])) if dp_axes else 1

    def leaf(path, x):
        spec = param_spec(_path_str(path), x.shape, cfg, model_size)
        spec = zero1_spec(spec, x.shape, dp_axes, dp_size, prefer_inner=True)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, shape_tree)


def opt_state_shardings(cfg, mesh: Mesh, opt_shape_tree: Any,
                        zero1: bool = False) -> Any:
    """Optimizer state mirrors param sharding (+ ZeRO-1 data sharding)."""
    model_size = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else \
        dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                           for a in dp_axes])) if dp_axes else 1

    def leaf(path, x):
        # strip the optimizer-state prefix (ms/mom/m/v/acc) to match params
        pstr = _path_str(path)
        pstr = re.sub(r"^(ms|mom|m|v|acc)/", "", pstr)
        spec = param_spec(pstr, x.shape, cfg, model_size)
        if zero1:
            spec = zero1_spec(spec, x.shape, dp_axes, dp_size)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, opt_shape_tree)


def batch_shardings(mesh: Mesh, batch_tree: Any, *,
                    seq_sharded: bool = False) -> Any:
    """Inputs: batch dim over ('pod','data'); [W] masks/scalars replicated.

    seq_sharded=True shards axis 1 (sequence) instead — the long-context
    decode layout where batch=1 (sequence parallelism).
    """
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    dp_size = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                           for a in dp_axes])) if dp_axes else 1

    def leaf(x):
        if x.ndim == 0 or x.shape[0] == 0:
            return NamedSharding(mesh, P())
        if seq_sharded:
            if x.ndim >= 2 and _div(x.shape[1], dp_size):
                return NamedSharding(mesh, P(None, dp))
            return NamedSharding(mesh, P())
        if _div(x.shape[0], dp_size):
            return NamedSharding(mesh, P(dp))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(leaf, batch_tree)


# ---------------------------------------------------------------------------
# Manual tensor parallelism for the SPMD engine (repro.distributed.tp)
# ---------------------------------------------------------------------------

# optimizer-state trees prefix their leaves (ms/mom/m/v/acc); strip the
# prefix so state leaves inherit the matching parameter's spec
_OPT_PREFIX = re.compile(r"^(ms|mom|m|v|acc)/")


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """Which parameter groups shard over the engine's manual 'model' axis.

    Unlike the per-leaf GSPMD rules above (where a non-divisible leaf can
    be replicated independently), manual TP must be **group-consistent**:
    the model runs with a locally-reshaped config, so either every leaf
    of a group shards or none does (wq sharded with wk replicated would
    change ``q_per_kv`` on the shard). :func:`tp_plan` encodes those
    rules; the booleans mirror :class:`repro.distributed.tp.TPContext`.
    """

    size: int = 1
    attn: bool = False                # wq/wk/wv out-dim, wo in-dim (heads)
    ffn: bool = False                 # w_up/w_gate out-dim, w_down in-dim
    vocab: bool = False               # embed rows, lm_head/head columns

    @property
    def any(self) -> bool:
        return self.attn or self.ffn or self.vocab


def tp_plan(model_cfg, model_size: int) -> TPPlan:
    """Group-consistency + divisibility rules for manual TP.

    * only the TransformerLM families carry the f/g psum hooks
      (``repro.models.transformer.block_apply``); other families run with
      the model axis replicated;
    * the attention group needs BOTH head counts divisible (contiguous
      q-head slices must align with their kv groups) and no biases (the
      row-parallel ``wo`` bias would be added ``size`` times before the
      psum);
    * the ffn group needs the dense-segment hidden width divisible and no
      biases (same row-parallel ``w_down`` argument);
    * the vocab group needs the padded vocab divisible (embedding rows /
      head columns are sliced contiguously);
    * MoE expert / router / ssm / rwkv leaves never shard here — the
      engine replicates them (their forward has no manual psum points).
    """
    m = model_size
    if m <= 1 or model_cfg is None or \
            model_cfg.family not in ("dense", "moe", "vlm"):
        return TPPlan(max(m, 1))
    attn = (model_cfg.attention_kind == "gqa" and not model_cfg.use_bias
            and model_cfg.num_heads % m == 0
            and model_cfg.num_kv_heads % m == 0)
    d_ff = (model_cfg.moe.dense_d_ff
            if (model_cfg.moe.enabled and model_cfg.moe.dense_d_ff)
            else model_cfg.d_ff)
    ffn = (not model_cfg.use_bias) and d_ff % m == 0 and d_ff >= m
    vocab = model_cfg.padded_vocab % m == 0 and model_cfg.padded_vocab >= m
    return TPPlan(m, attn, ffn, vocab)


def tp_local_model_cfg(model_cfg, plan: TPPlan):
    """The per-shard model config: head counts / hidden width divided by
    the axis size for the groups that shard. ``head_dim`` is pinned first
    so the derived ``resolved_head_dim`` cannot drift when ``num_heads``
    shrinks; vocab fields stay GLOBAL — the vocab group is handled by
    ``tp.sharded_embed`` / ``tp.sharded_cross_entropy``, which read the
    local slice size off the parameter itself."""
    if not plan.any:
        return model_cfg
    kw = {}
    if plan.attn:
        kw.update(head_dim=model_cfg.resolved_head_dim,
                  num_heads=model_cfg.num_heads // plan.size,
                  num_kv_heads=model_cfg.num_kv_heads // plan.size)
    if plan.ffn:
        kw["d_ff"] = model_cfg.d_ff // plan.size
        if model_cfg.moe.enabled and model_cfg.moe.dense_d_ff:
            kw["moe"] = dataclasses.replace(
                model_cfg.moe,
                dense_d_ff=model_cfg.moe.dense_d_ff // plan.size)
    return dataclasses.replace(model_cfg, **kw)


def tp_param_spec(path: str, shape: Tuple[int, ...], plan: TPPlan) -> P:
    """PartitionSpec of one leaf under the engine's manual TP plan.

    Narrower than :func:`param_spec` by design: only the three
    group-consistent TransformerLM groups shard; scalars, 1-D leaves
    (biases, norm scales) and every unmatched path are replicated. The
    leading layer-stack dimension of scanned segments is never sharded.
    """
    nd = len(shape)
    none = (None,) * nd

    def at(axis_idx: int) -> P:
        if not _div(shape[axis_idx], plan.size):
            return P(*none)
        spec = list(none)
        spec[axis_idx] = "model"
        return P(*spec)

    if plan.vocab and nd >= 2:
        if path.endswith("embed/embedding"):
            return at(0)
        if re.search(r"(lm_head|head)/w$", path):
            return at(nd - 1)
    if plan.attn and nd >= 2:
        if re.search(r"attn/(wq|wk|wv)/w$", path):
            return at(nd - 1)
        if re.search(r"attn/wo/w$", path):
            return at(nd - 2)
    if plan.ffn and nd >= 2:
        if re.search(r"mlp/(w_up|w_gate)/w$", path):
            return at(nd - 1)
        if re.search(r"mlp/w_down/w$", path):
            return at(nd - 2)
    return P(*none)


def tp_param_specs(plan: TPPlan, shape_tree: Any) -> Any:
    """Pytree of PartitionSpecs for a parameter (shape) tree."""

    def leaf(path, x):
        return tp_param_spec(_path_str(path), tuple(x.shape), plan)

    return jax.tree_util.tree_map_with_path(leaf, shape_tree)


def tp_state_specs(plan: TPPlan, state_shape_tree: Any) -> Any:
    """Specs for optimizer-state / EMA trees.

    The tree STRUCTURE may differ from params (rmsprop wraps the params
    tree under ``ms``/``mom``, adam under ``m``/``v``, sgd has no state
    at all); leaves are matched to their parameter by path suffix after
    stripping the optimizer prefix, so any params-shaped subtree inherits
    the parameter specs leaf-for-leaf.
    """

    def leaf(path, x):
        pstr = _OPT_PREFIX.sub("", _path_str(path))
        return tp_param_spec(pstr, tuple(x.shape), plan)

    return jax.tree_util.tree_map_with_path(leaf, state_shape_tree)


def cache_shardings(cfg, mesh: Mesh, cache_tree: Any) -> Any:
    """KV/state caches [B, S, heads, hd] (or [B, S, rank] / state tensors).

    Unified rule:
      * batch over ('pod','data') when divisible (decode_32k layout);
        otherwise the sequence axis takes the data axes (long_500k,
        batch=1 — sequence parallelism, partial-softmax psums);
      * the head axis takes 'model' when divisible (qwen2-moe kv=16);
        otherwise the sequence axis (additionally) takes 'model' — decode
        attention contracts S, so GSPMD lowers the softmax to psums over
        'model' (flash-decoding-style split-KV).
    """
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = names.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    dp_size = int(np.prod([names[a] for a in dp_axes])) if dp_axes else 1

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        spec: list = [None] * x.ndim
        batch_sharded = _div(x.shape[0], dp_size) and x.shape[0] >= dp_size
        if batch_sharded:
            spec[0] = dp
        if x.ndim >= 3 and _div(x.shape[2], model_size) and x.shape[2] >= model_size:
            spec[2] = "model"
        elif x.ndim >= 2:
            seq_axes = (() if batch_sharded else dp_axes) + ("model",)
            total = int(np.prod([names[a] for a in seq_axes]))
            if _div(x.shape[1], total) and x.shape[1] >= total:
                spec[1] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(leaf, cache_tree)
