"""Deterministic synthetic LM token pipeline.

Generates a learnable token stream — a mixture of (a) a fixed-order-k Markov
chain over the vocab (so models can reduce loss well below ln(V)) and (b)
uniform noise — seeded per (worker, step) so that:

  * every worker draws a DISJOINT batch shard (paper's workers sample
    independently from X);
  * the stream is exactly reproducible across restarts given (seed, step) —
    checkpoint/resume restores the pipeline by restoring the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_workers: int = 1
    seed: int = 0
    noise: float = 0.1       # probability of a uniform-random token
    order: int = 1           # Markov order of the deterministic skeleton


def _transition(vocab: int, seed: int) -> np.ndarray:
    """A fixed permutation-like transition: next = (a*tok + b) % V."""
    rng = np.random.RandomState(seed)
    a = int(rng.randint(1, vocab - 1)) | 1      # odd => full cycle for pow2 V
    b = int(rng.randint(0, vocab))
    return a, b


def worker_batch(cfg: SyntheticLMConfig, worker: int, step: int) -> Dict[str, np.ndarray]:
    """The [B/W, S] shard of the global batch for `worker` at `step`."""
    per_worker = cfg.global_batch // cfg.num_workers
    a, b = _transition(cfg.vocab_size, cfg.seed)
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) * 4097 + worker)
    start = rng.randint(0, cfg.vocab_size, size=(per_worker, 1))
    toks = [start]
    for _ in range(cfg.seq_len):
        nxt = (a * toks[-1] + b) % cfg.vocab_size
        toks.append(nxt)
    seq = np.concatenate(toks, axis=1)          # [b, S+1]
    noise_mask = rng.rand(per_worker, cfg.seq_len + 1) < cfg.noise
    noise_toks = rng.randint(0, cfg.vocab_size, size=seq.shape)
    seq = np.where(noise_mask, noise_toks, seq).astype(np.int32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def global_batch(cfg: SyntheticLMConfig, step: int) -> Dict[str, np.ndarray]:
    """Concatenation of all workers' shards — what the SPMD step consumes.

    Worker w owns rows [w*B/W, (w+1)*B/W); the sync-backup mask indexes
    workers by this row blocking (see repro.core.sync_backup).
    """
    shards = [worker_batch(cfg, w, step) for w in range(cfg.num_workers)]
    return {k: np.concatenate([s[k] for s in shards], axis=0) for k in shards[0]}


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def save(self) -> Dict:
        return {"step": self.step}

    @staticmethod
    def restore(d: Dict) -> "PipelineState":
        return PipelineState(step=int(d["step"]))


class SyntheticLMPipeline:
    """Stateful iterator with save/restore (checkpointable)."""

    def __init__(self, cfg: SyntheticLMConfig, state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.state = state or PipelineState()

    def next(self) -> Dict[str, np.ndarray]:
        batch = global_batch(self.cfg, self.state.step)
        self.state.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()
