"""Deterministic synthetic LM token pipeline.

Generates a learnable token stream — a mixture of (a) a fixed-order-k Markov
chain over the vocab (so models can reduce loss well below ln(V)) and (b)
uniform noise — seeded per (worker, step) so that:

  * every worker draws a DISJOINT batch shard (paper's workers sample
    independently from X);
  * the stream is exactly reproducible across restarts given (seed, step) —
    checkpoint/resume restores the pipeline by restoring the step counter.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_workers: int = 1
    seed: int = 0
    noise: float = 0.1       # probability of a uniform-random token
    order: int = 1           # Markov order of the deterministic skeleton


def _transition(vocab: int, seed: int) -> np.ndarray:
    """A fixed permutation-like transition: next = (a*tok + b) % V."""
    rng = np.random.RandomState(seed)
    a = int(rng.randint(1, vocab - 1)) | 1      # odd => full cycle for pow2 V
    b = int(rng.randint(0, vocab))
    return a, b


@functools.lru_cache(maxsize=64)
def _chain_tables(vocab: int, seed: int, seq_len: int):
    """Closed form of the affine chain: tok_t = (a^t*s0 + b*g_t) mod V with
    g_t = sum_{i<t} a^i. Precomputed per config so batch generation is one
    vectorized expression instead of a seq_len python loop (the loop was
    the host-pipeline bottleneck of the fused chunked trainer)."""
    a, b = _transition(vocab, seed)
    pow_a = np.empty(seq_len + 1, np.int64)
    geo = np.empty(seq_len + 1, np.int64)
    p, g = 1, 0
    for t in range(seq_len + 1):
        pow_a[t] = p
        geo[t] = g
        g = (g + p) % vocab
        p = (p * a) % vocab
    return pow_a, (b * geo) % vocab


def worker_batch(cfg: SyntheticLMConfig, worker: int, step: int) -> Dict[str, np.ndarray]:
    """The [B/W, S] shard of the global batch for `worker` at `step`."""
    per_worker = cfg.global_batch // cfg.num_workers
    pow_a, offset = _chain_tables(cfg.vocab_size, cfg.seed, cfg.seq_len)
    # % 2**32 keeps RandomState in range for large (seed, step); identity
    # for every in-range value, so existing streams are unchanged
    rng = np.random.RandomState(
        ((cfg.seed * 1_000_003 + step) * 4097 + worker) % (2 ** 32))
    start = rng.randint(0, cfg.vocab_size, size=(per_worker, 1))
    # bit-exact closed form of the step-by-step a*tok+b chain
    seq = (pow_a[None, :] * start + offset[None, :]) % cfg.vocab_size
    noise_mask = rng.rand(per_worker, cfg.seq_len + 1) < cfg.noise
    noise_toks = rng.randint(0, cfg.vocab_size, size=seq.shape)
    seq = np.where(noise_mask, noise_toks, seq).astype(np.int32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def global_batch(cfg: SyntheticLMConfig, step: int) -> Dict[str, np.ndarray]:
    """Concatenation of all workers' shards — what the SPMD step consumes.

    Worker w owns rows [w*B/W, (w+1)*B/W); the sync-backup mask indexes
    workers by this row blocking (see repro.core.sync_backup).
    """
    shards = [worker_batch(cfg, w, step) for w in range(cfg.num_workers)]
    return {k: np.concatenate([s[k] for s in shards], axis=0) for k in shards[0]}


def device_batch_fn(cfg: SyntheticLMConfig):
    """jnp twin of ``global_batch`` for the fully device-resident trainer.

    Returns batch_fn(step) -> {tokens, labels} built with `jax.random`
    inside the scan body — zero host work per step. Same Markov+noise
    distribution and the same per-(seed, step) determinism contract as the
    numpy pipeline, but NOT stream-identical to it (jax.random draws a
    different sequence); bit-exact replay against the host pipeline uses
    straggler_backend='host'.
    """
    if cfg.vocab_size > 46340:   # pow_a * start must fit int32 (no x64)
        raise NotImplementedError(
            "device_batch_fn needs vocab_size <= 46340; use the host pipeline")
    pow_a_np, offset_np = _chain_tables(cfg.vocab_size, cfg.seed, cfg.seq_len)
    pow_a = jnp.asarray(pow_a_np, jnp.int32)
    offset = jnp.asarray(offset_np, jnp.int32)
    # domain-separated from the straggler key stream (loop.py folds a
    # different tag), so data noise and arrival draws stay independent
    base = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 0xDA7A)

    def batch_fn(step):
        key = jax.random.fold_in(base, step)
        k_start, k_mask, k_noise = jax.random.split(key, 3)
        start = jax.random.randint(k_start, (cfg.global_batch, 1), 0,
                                   cfg.vocab_size, jnp.int32)
        seq = (pow_a[None, :] * start + offset[None, :]) % cfg.vocab_size
        noise = jax.random.uniform(k_mask, seq.shape) < cfg.noise
        noise_toks = jax.random.randint(k_noise, seq.shape, 0,
                                        cfg.vocab_size, jnp.int32)
        seq = jnp.where(noise, noise_toks, seq).astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    return batch_fn


def chunk_batches(cfg: SyntheticLMConfig, start_step: int, k: int
                  ) -> Dict[str, np.ndarray]:
    """K stacked global batches [K, B, ...] — one host->device transfer for
    the fused chunked trainer, bit-identical to k global_batch() calls."""
    batches = [global_batch(cfg, s) for s in range(start_step, start_step + k)]
    return {key: np.stack([b[key] for b in batches]) for key in batches[0]}


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def save(self) -> Dict:
        return {"step": self.step}

    @staticmethod
    def restore(d: Dict) -> "PipelineState":
        return PipelineState(step=int(d["step"]))


class SyntheticLMPipeline:
    """Stateful iterator with save/restore (checkpointable)."""

    def __init__(self, cfg: SyntheticLMConfig, state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.state = state or PipelineState()

    def next(self) -> Dict[str, np.ndarray]:
        batch = global_batch(self.cfg, self.state.step)
        self.state.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


class ChunkPrefetcher:
    """Look-ahead chunk generation for the fused trainer.

    After serving chunk [step, step+k) it speculatively builds up to
    ``depth`` upcoming chunks on background threads (depth=1 is classic
    double buffering), overlapping host batch generation with device
    compute. Generation is pure in (cfg, step), so a mispredicted
    boundary (checkpoint / kill-injection / final ragged chunk) just
    falls back to synchronous generation — determinism and checkpoint
    state are owned by the caller's PipelineState, never by the prefetch
    threads, and the served batches are identical at every depth.
    """

    def __init__(self, cfg: SyntheticLMConfig, depth: int = 1):
        if depth < 0:
            raise ValueError(f"prefetch depth must be >= 0 (got {depth})")
        self.cfg = cfg
        self.depth = depth
        # in-flight speculations, oldest first: [(spec, thread, holder)]
        self._pending: list = []

    def _launch(self, step: int, k: int) -> None:
        holder: Dict = {}

        def work():
            holder["chunk"] = chunk_batches(self.cfg, step, k)

        th = threading.Thread(target=work, daemon=True,
                              name="repro-chunk-prefetch")
        th.start()
        self._pending.append(((step, k), th, holder))

    def _take(self, step: int, k: int) -> Optional[Dict[str, np.ndarray]]:
        """Pop the speculation matching (step, k); reap stale ones."""
        chunk = None
        keep = []
        for spec, th, holder in self._pending:
            if spec == (step, k) and chunk is None:
                th.join()
                chunk = holder.get("chunk")
            elif spec[0] > step:
                keep.append((spec, th, holder))   # still ahead: may hit later
            else:
                th.join()                         # stale: reap and drop
        self._pending = keep
        return chunk

    def get(self, step: int, k: int, next_k: Optional[int] = None,
            next_specs: Optional[list] = None) -> Dict[str, np.ndarray]:
        """The stacked chunk for [step, step+k).

        ``next_specs`` is the caller's prediction of the FOLLOWING chunks
        as (step, k) pairs (the Trainer computes them from its boundary
        rules): the first ``depth`` not-yet-inflight ones are built on
        background threads while the device runs this chunk. ``next_k``
        is the depth-1 shorthand (equivalent to
        ``next_specs=[(step + k, next_k)]``). Empty/None means no
        speculation — e.g. the last chunk of a run."""
        if next_specs is None:
            next_specs = [(step + k, next_k)] if next_k else []
        chunk = self._take(step, k)
        if chunk is None:
            chunk = chunk_batches(self.cfg, step, k)
        inflight = {spec for spec, _, _ in self._pending}
        for spec in next_specs[:max(self.depth, 0)]:
            if len(self._pending) >= self.depth:
                break
            if tuple(spec) not in inflight:
                self._launch(*spec)
        return chunk
