"""Synthetic MNIST-like dataset for the paper's §2.1 staleness experiment.

10 classes of 28x28 images: each class is a fixed random low-frequency
template; samples are template + small random rotation/zoom (the paper's
augmentation) + pixel noise. Linearly separable enough that the 4-layer
CNN reaches ~99% — leaving visible headroom for staleness degradation,
mirroring the paper's 0.36% -> 0.79% error inflation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MnistLikeConfig:
    num_train: int = 8192
    num_test: int = 2048
    image_size: int = 28
    num_classes: int = 10
    seed: int = 0
    noise: float = 0.35
    augment: bool = True     # paper: small rotations and zooms


def _templates(cfg: MnistLikeConfig) -> np.ndarray:
    rng = np.random.RandomState(cfg.seed)
    n = cfg.image_size
    # low-frequency templates: random 7x7 upsampled bilinearly
    coarse = rng.randn(cfg.num_classes, 7, 7)
    xi = np.linspace(0, 6, n)
    x0 = np.floor(xi).astype(int).clip(0, 5)
    fx = xi - x0
    up = (coarse[:, x0][:, :, x0] * (1 - fx)[None, :, None] * (1 - fx)[None, None, :]
          + coarse[:, x0 + 1][:, :, x0] * fx[None, :, None] * (1 - fx)[None, None, :]
          + coarse[:, x0][:, :, x0 + 1] * (1 - fx)[None, :, None] * fx[None, None, :]
          + coarse[:, x0 + 1][:, :, x0 + 1] * fx[None, :, None] * fx[None, None, :])
    return up.astype(np.float32)


def _augment(imgs: np.ndarray, rng: np.random.RandomState) -> np.ndarray:
    """Small rotations (±10 deg) and zooms (±8%) via affine resampling."""
    n, h, w = imgs.shape
    out = np.empty_like(imgs)
    cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
    yy, xx = np.mgrid[0:h, 0:w]
    for i in range(n):
        th = rng.uniform(-0.17, 0.17)
        z = rng.uniform(0.92, 1.08)
        c, s = np.cos(th) / z, np.sin(th) / z
        sy = c * (yy - cy) - s * (xx - cx) + cy
        sx = s * (yy - cy) + c * (xx - cx) + cx
        y0 = np.clip(sy.astype(int), 0, h - 1)
        x0 = np.clip(sx.astype(int), 0, w - 1)
        out[i] = imgs[i, y0, x0]
    return out


def make_dataset(cfg: MnistLikeConfig) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    rng = np.random.RandomState(cfg.seed + 1)
    tpl = _templates(cfg)

    def sample(n: int, augment: bool):
        labels = rng.randint(0, cfg.num_classes, size=n)
        imgs = tpl[labels].copy()
        if augment and cfg.augment:
            imgs = _augment(imgs, rng)
        imgs += cfg.noise * rng.randn(*imgs.shape).astype(np.float32)
        return {"images": imgs[..., None].astype(np.float32),
                "labels": labels.astype(np.int32)}

    return sample(cfg.num_train, True), sample(cfg.num_test, False)


def batches(data: Dict[str, np.ndarray], batch_size: int, seed: int, steps: int):
    """Infinite shuffled batch iterator, deterministic in (seed, step)."""
    n = data["labels"].shape[0]
    for step in range(steps):
        rng = np.random.RandomState(seed * 7919 + step)
        idx = rng.randint(0, n, size=batch_size)
        yield {k: v[idx] for k, v in data.items()}
