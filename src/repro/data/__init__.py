from repro.data.synthetic_lm import (SyntheticLMConfig, SyntheticLMPipeline,
                                     global_batch, worker_batch)
from repro.data.mnist_like import MnistLikeConfig, make_dataset
