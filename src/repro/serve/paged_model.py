"""Paged prefill + decode: the jitted halves of the serve engine.

Two traced functions per engine, each compiled once (decode) or once per
prompt bucket (prefill):

* ``prefill(params, tokens[1, S_bucket], meta, pool)`` runs the full
  stack over one bucket-padded prompt (``meta`` packs ``[true_len,
  *page_ids]`` as one int32 vector), returns the greedy first token and
  the pool with the prompt's K/V scattered into the request's pages.
  Padding positions are written too (the scatter shape must be static per
  bucket) — they are masked by the decode validity rule (``kpos <= len``)
  until real decode tokens overwrite them.
* ``decode(params, state[S_slots, 2 + max_pages], pool)`` advances every
  slot one token. ``state`` packs per slot ``[last_token, len,
  *page_table_row]`` — one int32 host->device transfer per step, which is
  what the scheduler loop's wall clock is made of at smoke scale. Scatter
  the new K/V at ``len``, gather each slot's pages
  (``repro.kernels.page_gather``), attend under the per-slot validity +
  sliding-window mask, and return each slot's greedy next token (argmax
  stays on device; only ``[S]`` int32 comes back). Idle slots carry a
  zeroed page-table row, so their dead writes land on the reserved trash
  page and their tokens are ignored by the host.

Both run layers through ``lax.scan`` (HLO size O(1) in depth) and carry
the tensor-parallel f/g hooks exactly where ``transformer.block_apply``
puts them, so :func:`build_tp_paged_fns` can wrap the same bodies in
``shard_map`` over the mesh 'model' axis with a locally-reshaped config —
a ``mesh_model > 1`` checkpoint from training serves without resharding.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import tp
from repro.kernels.page_gather import gather_pages
from repro.models import attention, common, mlp, moe
from repro.models.common import Params
from repro.models.transformer import layer_windows_np, segments


def supports_paged(cfg) -> Tuple[bool, str]:
    """Families the paged serve path covers (mirrors decode_step support)."""
    if cfg.family not in ("dense", "moe"):
        return False, f"family {cfg.family!r} has no paged decode path"
    if cfg.attention_kind != "gqa":
        return False, (f"attention_kind {cfg.attention_kind!r} is not paged "
                       f"(MLA latents need their own page layout)")
    return True, ""


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _paged_attn(p_attn: Params, cfg, h, pool_l, lens, page_table, window, *,
                quantized: bool, use_kernel: bool, interpret: bool):
    """One layer's paged decode attention. h: [B, 1, d] (post-ln, post f)."""
    b = h.shape[0]
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ps = pool_l["k"].shape[1]
    pos = lens[:, None]                                   # per-slot positions
    q, k_new, v_new = attention._project_qkv(p_attn, cfg, h, pos)
    # scatter the new token's K/V into its slot's current page
    bidx = jnp.arange(b)
    pid = page_table[bidx, lens // ps]                    # idle rows -> trash
    off = lens % ps
    new_pool = dict(pool_l)
    if quantized:
        kq, ksc = attention._quantize_kv(k_new)
        vq, vsc = attention._quantize_kv(v_new)
        new_pool["k"] = pool_l["k"].at[pid, off].set(kq[:, 0])
        new_pool["v"] = pool_l["v"].at[pid, off].set(vq[:, 0])
        new_pool["k_scale"] = pool_l["k_scale"].at[pid, off].set(ksc[:, 0])
        new_pool["v_scale"] = pool_l["v_scale"].at[pid, off].set(vsc[:, 0])
        k = gather_pages(new_pool["k"], page_table, new_pool["k_scale"],
                         out_dtype=h.dtype, use_kernel=use_kernel,
                         interpret=interpret)
        v = gather_pages(new_pool["v"], page_table, new_pool["v_scale"],
                         out_dtype=h.dtype, use_kernel=use_kernel,
                         interpret=interpret)
    else:
        new_pool["k"] = pool_l["k"].at[pid, off].set(
            k_new[:, 0].astype(pool_l["k"].dtype))
        new_pool["v"] = pool_l["v"].at[pid, off].set(
            v_new[:, 0].astype(pool_l["v"].dtype))
        k = gather_pages(new_pool["k"], page_table, out_dtype=h.dtype,
                         use_kernel=use_kernel, interpret=interpret)
        v = gather_pages(new_pool["v"], page_table, out_dtype=h.dtype,
                         use_kernel=use_kernel, interpret=interpret)
    s = k.shape[1]                                        # max_pages * ps
    qg = q.reshape(b, kv, cfg.q_per_kv, hd)
    scores = jnp.einsum("bgqd,bsgd->bgqs", qg, k).astype(jnp.float32) \
        / math.sqrt(hd)
    scores = common.softcap(scores, cfg.attn_logit_softcap)
    kpos = jnp.arange(s)
    valid = (kpos[None, :] <= lens[:, None]) \
        & attention._window_ok(lens[:, None] - kpos[None, :], window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqs,bsgd->bgqd", probs.astype(v.dtype), v)
    out = out.reshape(b, 1, cfg.num_heads * hd)
    return common.dense(p_attn["wo"], out), new_pool


def _ffn(p_l: Params, cfg, kind: str, h2):
    if kind == "moe":
        b = h2.shape[0]
        out, _ = moe.moe_apply(p_l["moe"], cfg, h2.reshape(b * h2.shape[1], -1),
                               cfg.moe.capacity_factor)
        return out.reshape(h2.shape)
    h2 = tp.col_in(h2, "ffn")
    return tp.row_out(mlp.mlp_apply(p_l["mlp"], h2, cfg.hidden_act), "ffn")


def build_paged_decode(model, *, quantized: bool, use_kernel: bool = False,
                       interpret: bool = True,
                       gather_logits: Callable = None) -> Callable:
    """decode(params, state[B, 2+maxp], pool) -> (next_token [B], new pool).

    ``state[:, 0]`` last tokens, ``state[:, 1]`` lens, ``state[:, 2:]`` the
    page table. Greedy argmax happens in-graph; callers get int32 ids.
    ``gather_logits`` (TP) reassembles vocab-sharded logits first."""
    cfg = model.cfg
    windows = layer_windows_np(cfg)

    def decode(params, state, pool):
        state = state.astype(jnp.int32)
        tokens = state[:, 0:1]
        lens = state[:, 1]
        page_table = state[:, 2:]
        x = model._embed_inputs(params, tokens)
        new_segs = []
        for kind, count, first in segments(cfg):
            stacked = params[f"seg_{kind}"]
            seg_windows = jnp.asarray(windows[first:first + count])
            seg_pool = {n: b[first:first + count] for n, b in pool.items()}

            def body(h, xs, _kind=kind):
                p_l, win, pool_l = xs
                h1 = common.rmsnorm(p_l["ln1"], h, cfg.norm_eps)
                h1 = tp.col_in(h1, "attn")
                attn_out, pool_l = _paged_attn(
                    p_l["attn"], cfg, h1, pool_l, lens, page_table, win,
                    quantized=quantized, use_kernel=use_kernel,
                    interpret=interpret)
                h = h + tp.row_out(attn_out, "attn")
                h2 = common.rmsnorm(p_l["ln2"], h, cfg.norm_eps)
                return h + _ffn(p_l, cfg, _kind, h2), pool_l

            x, new_seg = jax.lax.scan(body, x, (stacked, seg_windows,
                                                seg_pool))
            new_segs.append(new_seg)
        new_pool = {n: jnp.concatenate([s[n] for s in new_segs], axis=0)
                    for n in pool}
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (tp.col_in(x, "vocab") @ model._output_weights(params))[:, 0]
        if gather_logits is not None:
            logits = gather_logits(logits)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_pool

    return decode


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def build_paged_prefill(model, *, quantized: bool,
                        gather_logits: Callable = None) -> Callable:
    """prefill(params, tokens[1,S_bucket], meta, pool)
    -> (first_token scalar int32, new pool). One compile per bucket.

    ``meta`` packs ``[true_len, *page_ids]`` as one int32 vector so an
    admission costs two host->device transfers, not four."""
    cfg = model.cfg
    windows = layer_windows_np(cfg)
    hd = cfg.resolved_head_dim

    def prefill(params, tokens, meta, pool):
        meta = meta.astype(jnp.int32)
        true_len, page_ids = meta[0], meta[1:]
        x = model._embed_inputs(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        s = x.shape[1]
        ks_all, vs_all = [], []
        for kind, count, first in segments(cfg):
            stacked = params[f"seg_{kind}"]
            seg_windows = jnp.asarray(windows[first:first + count])

            def body(h, xs, _kind=kind):
                p_l, win = xs
                h1 = common.rmsnorm(p_l["ln1"], h, cfg.norm_eps)
                h1 = tp.col_in(h1, "attn")
                # inline gqa_attend so the projected K/V can be captured
                # for the page scatter below
                q, k, v = attention._project_qkv(p_l["attn"], cfg, h1,
                                                 positions)
                ke = attention._expand_kv(k, cfg.q_per_kv)
                ve = attention._expand_kv(v, cfg.q_per_kv)
                scores = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(
                    jnp.float32) / math.sqrt(hd)
                scores = common.softcap(scores, cfg.attn_logit_softcap)
                mask = attention.make_attention_mask(s, s, window=win)
                scores = jnp.where(mask[None, None], scores, -1e30)
                probs = jax.nn.softmax(scores, axis=-1).astype(h1.dtype)
                out = jnp.einsum("bhqk,bkhd->bqhd", probs, ve)
                attn_out = common.dense(p_l["attn"]["wo"],
                                        out.reshape(1, s, -1))
                h = h + tp.row_out(attn_out, "attn")
                h2 = common.rmsnorm(p_l["ln2"], h, cfg.norm_eps)
                return h + _ffn(p_l, cfg, _kind, h2), (k[0], v[0])

            x, (ks, vs) = jax.lax.scan(body, x, (stacked, seg_windows))
            ks_all.append(ks)
            vs_all.append(vs)
        k_all = jnp.concatenate(ks_all, axis=0)        # [L, S, kv, hd]
        v_all = jnp.concatenate(vs_all, axis=0)
        x = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
        x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (tp.col_in(x, "vocab") @ model._output_weights(params))[0, 0]
        if gather_logits is not None:
            logits = gather_logits(logits)
        first_tok = jnp.argmax(logits).astype(jnp.int32)
        # scatter the prompt K/V (bucket-padded: static shape per bucket)
        num_l = k_all.shape[0]
        ps = pool["k"].shape[2]
        n_pages = s // ps
        kv = k_all.shape[2]
        new_pool = dict(pool)

        def paged(a, tail):
            return a.reshape((num_l, n_pages, ps) + tail)

        if quantized:
            kq, ksc = attention._quantize_kv(k_all)
            vq, vsc = attention._quantize_kv(v_all)
            new_pool["k"] = pool["k"].at[:, page_ids].set(paged(kq, (kv, hd)))
            new_pool["v"] = pool["v"].at[:, page_ids].set(paged(vq, (kv, hd)))
            new_pool["k_scale"] = pool["k_scale"].at[:, page_ids].set(
                paged(ksc, (kv,)))
            new_pool["v_scale"] = pool["v_scale"].at[:, page_ids].set(
                paged(vsc, (kv,)))
        else:
            new_pool["k"] = pool["k"].at[:, page_ids].set(
                paged(k_all, (kv, hd)).astype(pool["k"].dtype))
            new_pool["v"] = pool["v"].at[:, page_ids].set(
                paged(v_all, (kv, hd)).astype(pool["v"].dtype))
        return first_tok, new_pool

    return prefill


# ---------------------------------------------------------------------------
# Tensor-parallel wrappers (mesh 'model' axis, shard_map)
# ---------------------------------------------------------------------------


def tp_pool_specs(plan, quantized: bool) -> Dict[str, P]:
    """PartitionSpecs for the pool buffers: the kv-head axis shards with
    the attention group (wk/wv columns), everything else is replicated."""
    kv_axis = "model" if plan.attn else None
    payload = P(None, None, None, kv_axis, None)
    specs = {"k": payload, "v": payload}
    if quantized:
        scale = P(None, None, None, kv_axis)
        specs.update(k_scale=scale, v_scale=scale)
    return specs


def build_tp_paged_fns(model_cfg, mesh, params_template, *, quantized: bool,
                       use_kernel: bool = False, interpret: bool = True):
    """shard_map'd (prefill, decode) over the mesh 'model' axis.

    Params arrive FULL (gathered, as checkpoints are stored) and are
    sharded by the returned NamedShardings — the same ``tp_param_specs``
    the training engine uses, so a TP-trained checkpoint needs no
    resharding. Returns ``(prefill, decode, plan, param_shardings,
    pool_shardings)``; vocab-sharded logits are all-gathered in-graph
    before the greedy argmax, so tokens match the replicated path
    exactly.
    """
    from repro.distributed import sharding as sharding_lib
    from repro.distributed.spmd_engine import (MODEL_AXIS, _shard_map,
                                               resolve_tp)
    from repro.models import get_model

    plan = resolve_tp(model_cfg, mesh)
    local_cfg = sharding_lib.tp_local_model_cfg(model_cfg, plan)
    local_model = get_model(local_cfg)
    ctx = tp.TPContext(MODEL_AXIS, plan.attn, plan.ffn, plan.vocab)
    param_specs = sharding_lib.tp_param_specs(plan, params_template)
    pool_specs = tp_pool_specs(plan, quantized)

    def gather_vocab(logits):
        if plan.vocab:
            return jax.lax.all_gather(logits, MODEL_AXIS, axis=-1, tiled=True)
        return logits

    decode_core = build_paged_decode(local_model, quantized=quantized,
                                     use_kernel=use_kernel,
                                     interpret=interpret,
                                     gather_logits=gather_vocab)
    prefill_core = build_paged_prefill(local_model, quantized=quantized,
                                       gather_logits=gather_vocab)

    def decode_body(params, state, pool):
        with tp.tensor_parallel(ctx):
            return decode_core(params, state, pool)

    def prefill_body(params, tokens, meta, pool):
        with tp.tensor_parallel(ctx):
            return prefill_core(params, tokens, meta, pool)

    decode = _shard_map(decode_body, mesh,
                        in_specs=(param_specs, P(), pool_specs),
                        out_specs=(P(), pool_specs))
    prefill = _shard_map(prefill_body, mesh,
                         in_specs=(param_specs, P(), P(), pool_specs),
                         out_specs=(P(), pool_specs))
    param_shardings = jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs,
        is_leaf=lambda x: isinstance(x, P))
    pool_shardings = {n: NamedSharding(mesh, spec)
                      for n, spec in pool_specs.items()}
    return prefill, decode, plan, param_shardings, pool_shardings
