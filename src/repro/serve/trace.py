"""Open-loop arrival traces: seeded, replayable request streams.

Requests arrive on an *open loop* — arrival times are independent of how
fast the server drains them (the offered load is a property of the trace,
not the server), which is what makes p50/p99-vs-load curves meaningful.

Arrivals reuse the checkpointable :class:`~repro.core.coordination.
EventScheduler` machinery: ``sources`` independent arrival processes with
exponential inter-arrival times share one heap, and the scheduler's RNG
discipline (one draw per reschedule) makes the merged stream a Poisson-ish
process of aggregate rate ``rate`` that replays bit-identically for the
same :class:`TraceConfig` — the same contract the training event loops
rely on.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.coordination import EventScheduler
from repro.core.straggler import LatencyModel


@dataclasses.dataclass(frozen=True)
class ExpInterarrival(LatencyModel):
    """Exponential inter-arrival times (one Poisson source)."""

    mean: float = 1.0

    def sample(self, rng, shape):
        return rng.exponential(self.mean, size=shape)


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request from the trace."""

    rid: int
    arrival: float                 # seconds (or virtual units) from t=0
    prompt: np.ndarray             # [prompt_len] int32 token ids
    max_new: int                   # token budget incl. the prefill sample

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """A replayable open-loop trace is a pure function of this config."""

    num_requests: int = 32
    rate: float = 8.0              # aggregate arrivals per time unit
    sources: int = 4               # independent Poisson arrival sources
    prompt_len_min: int = 4
    prompt_len_max: int = 24
    max_new_min: int = 4
    max_new_max: int = 24
    vocab: int = 256
    seed: int = 0


def make_trace(tc: TraceConfig) -> List[Request]:
    """Materialize the trace: ``num_requests`` requests sorted by arrival."""
    if tc.rate <= 0:
        raise ValueError(f"rate must be > 0 (got {tc.rate})")
    sources = max(1, min(tc.sources, tc.num_requests))
    sched = EventScheduler(sources, ExpInterarrival(sources / tc.rate),
                           seed=tc.seed)
    rng = np.random.RandomState(tc.seed + 1)
    out: List[Request] = []
    for rid in range(tc.num_requests):
        t, src = sched.pop()
        sched.push(t, src)
        plen = int(rng.randint(tc.prompt_len_min, tc.prompt_len_max + 1))
        max_new = int(rng.randint(tc.max_new_min, tc.max_new_max + 1))
        prompt = rng.randint(0, tc.vocab, size=plen).astype(np.int32)
        out.append(Request(rid, float(t), prompt, max_new))
    out.sort(key=lambda r: (r.arrival, r.rid))
    return out


def bucket_for(length: int, *, floor: int, cap: int = 1 << 30) -> int:
    """Power-of-two padding bucket: the compile-once contract for prefill."""
    b = floor
    while b < length:
        b *= 2
    if b > cap:
        raise ValueError(f"length {length} exceeds the bucket cap {cap}")
    return b


def trace_buckets(trace: List[Request], *, floor: int,
                  cap: int) -> Tuple[int, ...]:
    """Distinct prompt buckets a trace will compile (ascending)."""
    return tuple(sorted({bucket_for(r.prompt_len, floor=floor, cap=cap)
                         for r in trace}))
