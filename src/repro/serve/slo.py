"""SLO-driven admission control for the replica router.

A windowed p99-latency estimator feeding a shed/queue decision — the
serving-side twin of ``DynamicBackup``'s sorted-window cutoff estimator
(``core/coordination.py``): both keep a bounded window of observed
latencies and turn an order statistic into a control action every
observation. Here the action is admission:

* ``observe(latency)`` pushes a completed request's latency into the
  window (bounded, FIFO) and recomputes the estimate.
* ``admit(now)`` answers *"take this arrival?"* — ``"admit"``,
  ``"shed"`` (drop with a structured rejection) or ``"queue"`` (hold in
  the router's waiting room until the controller re-opens).

The controller is hysteretic: it trips into violation when the windowed
p99 exceeds ``target_p99``, and only re-admits once the estimate falls
back under ``target_p99 * resume_margin`` — without the margin, shedding
immediately lowers the estimate and the controller chatters open/shut.

All state (window, mode, trip counters) round-trips through
``state_dict``/``load_state_dict`` so a router checkpoint resumes with
the exact controller dynamics (ISSUE 8 acceptance: checkpoint/restore
mid-run must not change a single admit/shed decision).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.obs.quantiles import windowed_quantile

SLO_MODES = ("off", "shed", "queue")


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Admission-control policy knobs (all times in router clock units)."""

    target_p99: float              # the SLO: windowed p99 latency ceiling
    mode: str = "shed"             # off | shed | queue
    window: int = 64               # latency observations kept
    min_samples: int = 8           # below this the controller stays open
    quantile: float = 99.0         # which order statistic to control on
    resume_margin: float = 0.8     # re-admit under target * margin
    probe_every: int = 4           # shed mode: admit every k-th arrival
                                   # as a probe so the estimator keeps
                                   # seeing fresh latencies (0: no probes)

    def __post_init__(self):
        if self.mode not in SLO_MODES:
            raise ValueError(f"slo mode must be one of {SLO_MODES} "
                             f"(got {self.mode!r})")
        if self.target_p99 <= 0:
            raise ValueError("target_p99 must be positive")
        if not 0 < self.resume_margin <= 1:
            raise ValueError("resume_margin must be in (0, 1]")


class SLOController:
    """Windowed-percentile admission gate with hysteresis."""

    def __init__(self, cfg: SLOConfig):
        self.cfg = cfg
        self.window: List[float] = []
        self.violating = False
        self.shed_count = 0
        self.queue_count = 0
        self.probes = 0
        self.trips = 0                 # open -> violating transitions
        self._since_probe = 0

    # -- estimate -------------------------------------------------------------

    def estimate(self) -> float:
        """Current windowed p-``quantile`` latency (0 until warm)."""
        return windowed_quantile(self.window, self.cfg.quantile,
                                 self.cfg.min_samples, 0.0)

    def observe(self, latency: float) -> None:
        self.window.append(float(latency))
        if len(self.window) > self.cfg.window:
            self.window.pop(0)
        est = self.estimate()
        if not self.violating:
            if est > self.cfg.target_p99:
                self.violating = True
                self.trips += 1
        elif est < self.cfg.target_p99 * self.cfg.resume_margin:
            self.violating = False

    # -- the gate -------------------------------------------------------------

    def admit(self, now: float) -> str:
        """Decision for one arrival: "admit" | "shed" | "queue"."""
        if self.cfg.mode == "off" or not self.violating:
            return "admit"
        if self.cfg.mode == "shed":
            # without probes a tripped shed gate would latch shut: shed
            # arrivals never complete, so the window would freeze above
            # target and nothing could ever re-open it
            self._since_probe += 1
            if self.cfg.probe_every \
                    and self._since_probe >= self.cfg.probe_every:
                self._since_probe = 0
                self.probes += 1
                return "admit"
            self.shed_count += 1
            return "shed"
        self.queue_count += 1
        return "queue"

    # -- checkpointable state -------------------------------------------------

    def state_dict(self) -> Dict:
        return {"window": [float(x) for x in self.window],
                "violating": bool(self.violating),
                "shed_count": int(self.shed_count),
                "queue_count": int(self.queue_count),
                "probes": int(self.probes),
                "trips": int(self.trips),
                "since_probe": int(self._since_probe)}

    def load_state_dict(self, d: Dict) -> None:
        self.window = [float(x) for x in d["window"]]
        self.violating = bool(d["violating"])
        self.shed_count = int(d["shed_count"])
        self.queue_count = int(d["queue_count"])
        self.probes = int(d["probes"])
        self.trips = int(d["trips"])
        self._since_probe = int(d["since_probe"])
