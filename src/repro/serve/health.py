"""Replica health tracking for the router: up / slow / down, and when.

The router consumes the chaos engine's fault grammar at replica scope
(``kind@step:rN`` — ``core/faults.py``) and this module is where those
faults become routing state. A replica is one of:

* ``"up"`` — dispatchable.
* ``"slow"`` — dispatchable but serving at ``factor``x step time until
  the slowdown window closes (the router's hedging exists precisely to
  route around these).
* ``"down"`` — crashed or preempted: not dispatchable; its in-flight
  requests were drained back to the router queue. A ``restart`` fault
  (or a preemption's built-in return) re-admits it.

Every transition lands in a structured, wall-clock-free event log (the
serving twin of the supervisor's ``recovery_log`` — docs/api.md), so a
same-seed chaos replay produces a bit-identical health history.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

HEALTH_STATES = ("up", "slow", "down")


@dataclasses.dataclass
class _Replica:
    state: str = "up"
    slow_factor: float = 1.0
    slow_until: float = -1.0       # router-clock time the slowdown ends
    up_at: float = -1.0            # scheduled restart time when down
    crashes: int = 0
    preempts: int = 0
    restarts: int = 0


class HealthMonitor:
    """Track R replicas' health and the transition log."""

    def __init__(self, num_replicas: int):
        if num_replicas < 1:
            raise ValueError("need at least one replica")
        self.replicas = [_Replica() for _ in range(num_replicas)]
        self.log: List[Dict[str, Any]] = []

    # -- transitions (driven by the router's fault loop) ----------------------

    def mark_down(self, r: int, now: float, *, reason: str,
                  up_at: float = -1.0) -> None:
        rep = self.replicas[r]
        rep.state = "down"
        rep.slow_factor, rep.slow_until = 1.0, -1.0
        rep.up_at = up_at
        if reason == "preempt":          # two distinct fault kinds: keep
            rep.preempts += 1            # the metrics distinguishable
        else:
            rep.crashes += 1
        self.log.append({"event": "down", "replica": r, "t": float(now),
                         "reason": reason})

    def revive(self, r: int, now: float) -> None:
        rep = self.replicas[r]
        rep.state = "up"
        rep.up_at = -1.0
        rep.restarts += 1
        self.log.append({"event": "up", "replica": r, "t": float(now)})

    def set_slowdown(self, r: int, now: float, *, factor: float,
                     until: float) -> None:
        rep = self.replicas[r]
        if rep.state == "down":
            return                  # a dead replica cannot also be slow
        rep.state = "slow"
        rep.slow_factor, rep.slow_until = float(factor), float(until)
        self.log.append({"event": "slow", "replica": r, "t": float(now),
                         "factor": float(factor), "until": float(until)})

    # -- queries --------------------------------------------------------------

    def expire(self, now: float) -> None:
        """Close elapsed slowdown windows; fire due scheduled restarts."""
        for r, rep in enumerate(self.replicas):
            if rep.state == "slow" and now >= rep.slow_until:
                rep.state = "up"
                rep.slow_factor, rep.slow_until = 1.0, -1.0
                self.log.append({"event": "recovered", "replica": r,
                                 "t": float(now)})
            elif rep.state == "down" and 0 <= rep.up_at <= now:
                self.revive(r, now)

    def is_up(self, r: int) -> bool:
        return self.replicas[r].state != "down"

    def factor(self, r: int, now: float) -> float:
        rep = self.replicas[r]
        if rep.state == "slow" and now < rep.slow_until:
            return rep.slow_factor
        return 1.0

    def up_replicas(self) -> List[int]:
        return [r for r, rep in enumerate(self.replicas)
                if rep.state != "down"]

    def next_restart(self) -> float:
        """Earliest scheduled revive among down replicas (inf if none)."""
        times = [rep.up_at for rep in self.replicas
                 if rep.state == "down" and rep.up_at >= 0]
        return min(times) if times else float("inf")

    def counts(self) -> Dict[str, int]:
        return {"crashes": sum(r.crashes for r in self.replicas),
                "preempts": sum(r.preempts for r in self.replicas),
                "restarts": sum(r.restarts for r in self.replicas)}
