"""Serving subsystem: continuous batching over a paged, TP-shardable KV
cache, fronted by a resilient replica router (docs/serving.md).

* :mod:`repro.serve.trace` — seeded open-loop arrival traces.
* :mod:`repro.serve.pages` — the shared page pool (+ int8 scale tables).
* :mod:`repro.serve.paged_model` — jitted paged prefill/decode, TP wrap.
* :mod:`repro.serve.engine` — the scheduler/engine, per-replica
  ``StepSession`` surface, and checkpoint bridge.
* :mod:`repro.serve.router` — hedged backups, timeout/retry, failover.
* :mod:`repro.serve.slo` — windowed-p99 SLO admission controller.
* :mod:`repro.serve.health` — replica up/slow/down tracking.
"""
from repro.serve.engine import (CompletedRequest, ServeEngine, ServeReport,
                                SERVE_FAULT_KINDS, SERVE_POLICIES,
                                StepSession, restore_params)
from repro.serve.health import HEALTH_STATES, HealthMonitor
from repro.serve.pages import PagePool, PoolConfig, pages_for
from repro.serve.paged_model import supports_paged
from repro.serve.router import (ROUTER_FAULT_KINDS, ReplicaRouter,
                                RouterCompleted, RouterConfig, RouterReport)
from repro.serve.slo import SLO_MODES, SLOConfig, SLOController
from repro.serve.trace import (Request, TraceConfig, bucket_for, make_trace,
                               trace_buckets)

__all__ = [
    "CompletedRequest", "HEALTH_STATES", "HealthMonitor", "PagePool",
    "PoolConfig", "ROUTER_FAULT_KINDS", "ReplicaRouter", "Request",
    "RouterCompleted", "RouterConfig", "RouterReport", "SERVE_FAULT_KINDS",
    "SERVE_POLICIES", "SLO_MODES", "SLOConfig", "SLOController",
    "ServeEngine", "ServeReport", "StepSession", "TraceConfig", "bucket_for",
    "make_trace", "pages_for", "restore_params", "supports_paged",
    "trace_buckets",
]
