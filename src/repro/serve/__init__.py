"""Serving subsystem: continuous batching over a paged, TP-shardable KV
cache (docs/serving.md).

* :mod:`repro.serve.trace` — seeded open-loop arrival traces.
* :mod:`repro.serve.pages` — the shared page pool (+ int8 scale tables).
* :mod:`repro.serve.paged_model` — jitted paged prefill/decode, TP wrap.
* :mod:`repro.serve.engine` — the scheduler/engine and checkpoint bridge.
"""
from repro.serve.engine import (CompletedRequest, ServeEngine, ServeReport,
                                SERVE_FAULT_KINDS, SERVE_POLICIES,
                                restore_params)
from repro.serve.pages import PagePool, PoolConfig, pages_for
from repro.serve.paged_model import supports_paged
from repro.serve.trace import (Request, TraceConfig, bucket_for, make_trace,
                               trace_buckets)

__all__ = [
    "CompletedRequest", "PagePool", "PoolConfig", "Request", "ServeEngine",
    "ServeReport", "SERVE_FAULT_KINDS", "SERVE_POLICIES", "TraceConfig",
    "bucket_for", "make_trace", "pages_for", "restore_params",
    "supports_paged", "trace_buckets",
]
