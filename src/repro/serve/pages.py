"""Paged KV cache: fixed-size pages allocated per request from a shared pool.

The device side is one stacked buffer per tensor — ``k``/``v`` of shape
``[L, num_pages, page_size, kv_heads, head_dim]`` (plus per-page f16 scale
tables ``[L, num_pages, page_size, kv_heads]`` when quantized) — shared by
every layer through a single host-side page table: a request's logical page
``i`` lives at the same physical page id across all layers, so one
``[num_slots, max_pages]`` int32 table drives every layer's gather.

Physical page 0 is the **trash page**: it is never handed out by the
allocator, and idle decode slots (zeroed page-table rows) scatter their
dead writes there. Allocation/free is pure host bookkeeping (a free list);
the device buffers are only ever touched by the jitted prefill/decode
functions in :mod:`repro.serve.paged_model`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static geometry of a page pool (one per ServeEngine)."""

    num_layers: int
    kv_heads: int
    head_dim: int
    num_pages: int                 # total physical pages incl. the trash page
    page_size: int                 # tokens per page (power of two)
    num_slots: int                 # concurrent decode slots
    max_pages_per_slot: int        # page-table width (static decode shape)
    quantized: bool = False        # int8 payload + per-(pos, head) f16 scales

    def __post_init__(self):
        if self.page_size & (self.page_size - 1):
            raise ValueError(f"page_size must be a power of two "
                             f"(got {self.page_size})")
        if self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")

    @property
    def tokens_per_slot(self) -> int:
        return self.max_pages_per_slot * self.page_size


class PagePool:
    """Host allocator + device buffers for the paged KV cache."""

    def __init__(self, pool_cfg: PoolConfig, dtype=jnp.float32,
                 shardings: Optional[Dict[str, jax.sharding.Sharding]] = None):
        self.cfg = pool_cfg
        c = pool_cfg
        shape = (c.num_layers, c.num_pages, c.page_size, c.kv_heads, c.head_dim)
        payload_dtype = jnp.int8 if c.quantized else dtype
        bufs: Dict[str, jnp.ndarray] = {
            "k": jnp.zeros(shape, payload_dtype),
            "v": jnp.zeros(shape, payload_dtype),
        }
        if c.quantized:
            sshape = shape[:-1]
            bufs["k_scale"] = jnp.zeros(sshape, jnp.float16)
            bufs["v_scale"] = jnp.zeros(sshape, jnp.float16)
        if shardings:
            bufs = {k: jax.device_put(v, shardings[k])
                    for k, v in bufs.items()}
        self.buffers = bufs
        # -- host bookkeeping: page 0 reserved as the trash page ------------
        self._free: List[int] = list(range(c.num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self.page_table = np.zeros((c.num_slots, c.max_pages_per_slot),
                                   np.int32)
        self.peak_pages = 0
        self._occupancy_sum = 0.0
        self._occupancy_n = 0

    # -- allocation -----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.cfg.num_pages - 1) - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, slot: int, n: int) -> np.ndarray:
        """Reserve ``n`` pages for ``slot``; returns their physical ids."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already holds pages")
        if n > self.cfg.max_pages_per_slot:
            raise ValueError(
                f"request needs {n} pages but the page table is only "
                f"{self.cfg.max_pages_per_slot} wide")
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        ids = np.array([self._free.pop() for _ in range(n)], np.int32)
        self._owned[slot] = list(ids)
        self.page_table[slot, :n] = ids
        self.page_table[slot, n:] = 0
        self.peak_pages = max(self.peak_pages, self.used_pages)
        return ids

    def try_alloc(self, slot: int, n: int) -> Optional[np.ndarray]:
        """Graceful :meth:`alloc`: ``None`` when ``n`` pages cannot be
        reserved (free-list exhaustion or a page-table row too narrow)
        instead of raising — the caller degrades (rejects/requeues with a
        structured reason) rather than dying mid-admission."""
        if (slot in self._owned or n > self.cfg.max_pages_per_slot
                or n > len(self._free)):
            return None
        return self.alloc(slot, n)

    def free_slot(self, slot: int) -> None:
        """Return ``slot``'s pages to the pool (evict/complete)."""
        for pid in self._owned.pop(slot, []):
            self._free.append(pid)
        self.page_table[slot] = 0

    # -- occupancy telemetry --------------------------------------------------

    def occupancy(self) -> float:
        return self.used_pages / (self.cfg.num_pages - 1)

    def note_occupancy(self) -> None:
        self._occupancy_sum += self.occupancy()
        self._occupancy_n += 1

    def mean_occupancy(self) -> float:
        return self._occupancy_sum / max(self._occupancy_n, 1)


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` positions."""
    return -(-tokens // page_size)
