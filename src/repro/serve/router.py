"""Replica router: hedged backups, SLO admission, chaos-proof failover.

The serving-side completion of the paper's story. Training-side,
*Revisiting Distributed Synchronous SGD* cuts the straggler tail by
launching N+b backup workers and taking the first N gradients; this
router applies the same cutoff idea at request granularity (the
"tail at scale" trick): when an in-flight request's age crosses a
windowed latency percentile, re-dispatch it to a second replica, take
whichever copy finishes first, and cancel-and-free the loser's slots
and pages. Greedy decode makes the two copies token-identical, so
hedging buys latency and never changes output.

Everything runs on one deterministic virtual clock owned by the router
(replicas are :class:`~repro.serve.engine.StepSession` surfaces — they
keep no time of their own), so a same-seed run is bit-for-bit
replayable even under chaos:

* **Faults** come from ``core/faults.py``'s grammar at replica scope
  (``kind@step:rN[:xF][:dD]``): ``crash`` downs a replica until an
  explicit ``restart``; ``preempt`` downs it for ``duration`` steps and
  auto-revives; ``slowdown`` stretches its step time by ``factor``.
  A downed replica's in-flight requests drain back to the router queue
  and re-dispatch in arrival order — zero requests are ever lost.
* **Timeouts** cancel an attempt everywhere and retry it after a
  seeded, jittered, capped exponential backoff (the same schedule shape
  as ``checkpoint.retry_delays``); past the retry budget the request is
  *rejected with a structured reason*, never dropped silently.
* **SLO admission** (``serve/slo.py``) gates fresh arrivals on a
  windowed p99 estimate: shed or hold load while the SLO is violated,
  re-admit under hysteresis.

Every request in the trace is accounted for: ``completed`` plus
``rejected`` always partitions the trace (``metrics["lost_requests"]``
asserts the invariant the chaos tests rely on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import faults as faults_lib
from repro.obs.quantiles import windowed_quantile
from repro.obs.trace import as_tracer
from repro.serve import trace as trace_lib
from repro.serve.engine import ServeEngine, StepSession
from repro.serve.health import HealthMonitor
from repro.serve.slo import SLOConfig, SLOController

ROUTER_FAULT_KINDS = ("crash", "preempt", "slowdown", "restart")


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Router policy knobs (all times in virtual clock units)."""

    num_replicas: int
    step_time: float = 1.0         # decode-step duration per replica
    prefill_time: float = 1.0      # admission (prefill) duration
    # -- timeout + retry ------------------------------------------------------
    timeout: Optional[float] = None       # per-attempt deadline (None: off)
    max_retries: int = 2
    backoff: float = 1.0                  # base retry delay
    max_backoff: float = 8.0              # cap on the exponential
    jitter: float = 0.5                   # delay *= 1 + jitter*U[0,1)
    seed: int = 0                         # jitter RNG seed
    # -- hedged backup requests ----------------------------------------------
    hedge_after: Optional[float] = None   # floor age to hedge (None: off)
    hedge_quantile: float = 95.0          # windowed percentile trigger
    hedge_min_samples: int = 8            # below this, floor alone applies
    hedge_window: int = 64                # completed latencies kept
    # -- load + chaos ---------------------------------------------------------
    max_queue: Optional[int] = None       # waiting-room bound (None: inf)
    faults: Optional[str] = None          # replica-scope fault spec
    fault_horizon: int = 256
    fault_seed: int = 0

    def __post_init__(self):
        if self.num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        if self.step_time <= 0:
            raise ValueError("step_time must be positive")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")


@dataclasses.dataclass
class RouterCompleted:
    rid: int
    arrival: float
    admitted: float        # dispatch time of the winning copy
    first_token: float
    finish: float
    prompt_len: int
    tokens: List[int]
    replica: int           # replica that produced the winning copy
    hedged: bool = False   # a backup copy was issued at some point
    retries: int = 0       # timeout retries consumed
    drains: int = 0        # failover requeues survived

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival


@dataclasses.dataclass
class RouterReport:
    completed: List[RouterCompleted]
    rejected: List[Dict[str, Any]]     # {"rid", "reason", "t"}
    metrics: Dict[str, float]
    events: List[Dict[str, Any]]       # router decisions (hedge/timeout/...)
    health: List[Dict[str, Any]]       # replica up/slow/down transitions

    def tokens_by_rid(self) -> Dict[int, List[int]]:
        return {c.rid: list(c.tokens) for c in self.completed}


class _Flight:
    """Router-side request state across dispatches."""

    __slots__ = ("req", "state", "primary", "hedge", "dispatch_t",
                 "deadline", "retries", "drains", "was_hedged")

    def __init__(self, req: trace_lib.Request):
        self.req = req
        self.state = "pending"     # pending|waiting|held|inflight|done|rejected
        self.primary = -1
        self.hedge = -1
        self.dispatch_t = -1.0
        self.deadline = float("inf")
        self.retries = 0
        self.drains = 0
        self.was_hedged = False


class ReplicaRouter:
    """Deterministic event-driven router over R StepSession replicas."""

    def __init__(self, engine: ServeEngine, cfg: RouterConfig,
                 slo: Optional[SLOConfig] = None, tracer=None, metrics=None):
        self.engine = engine
        self.cfg = cfg
        self.slo_cfg = slo
        # observability only: the tracer marks dispatch/hedge/timeout/
        # failover instants and the registry mirrors the counters. The
        # virtual-clock dynamics (and the returned metrics dict) never
        # read either, so replays stay bit-identical with or without.
        self.tracer = as_tracer(tracer)
        self.metrics = metrics
        self.fault_plan = None
        if cfg.faults:
            plan = faults_lib.plan_from_spec(
                cfg.faults, num_steps=cfg.fault_horizon,
                num_workers=cfg.num_replicas, seed=cfg.fault_seed,
                num_replicas=cfg.num_replicas)
            bad = sorted({e.kind for e in plan.events
                          if e.kind not in ROUTER_FAULT_KINDS})
            if bad:
                raise ValueError(
                    f"router wires only {ROUTER_FAULT_KINDS} of the fault "
                    f"taxonomy (ckpt_io has no serving surface); got {bad}")
            for e in plan.events:
                if not 0 <= e.replica < cfg.num_replicas:
                    raise ValueError(
                        f"fault {e.kind}@{e.step} targets replica "
                        f"{e.replica} but the router has "
                        f"{cfg.num_replicas} replicas")
            self.fault_plan = plan

    # -- hedging threshold ----------------------------------------------------

    def _hedge_threshold(self, lat_window: List[float]) -> Optional[float]:
        cfg = self.cfg
        if cfg.hedge_after is None:
            return None
        # cold window -> -inf -> max() returns the floor: identical to
        # the pre-extraction two-branch logic, bit for bit
        est = windowed_quantile(lat_window, cfg.hedge_quantile,
                                cfg.hedge_min_samples,
                                default=float("-inf"))
        return max(est, cfg.hedge_after)

    # -- the event loop -------------------------------------------------------

    def run(self, trace: Sequence[trace_lib.Request]) -> RouterReport:
        cfg = self.cfg
        eng = self.engine
        tracer = self.tracer
        for r in trace:
            eng.validate_request(r)
        sessions = [StepSession(eng, name=f"r{i}")
                    for i in range(cfg.num_replicas)]
        health = HealthMonitor(cfg.num_replicas)
        slo = SLOController(self.slo_cfg) if self.slo_cfg else None
        rng = np.random.RandomState(cfg.seed)

        arrivals = sorted(trace, key=lambda r: (r.arrival, r.rid))
        flights = {r.rid: _Flight(r) for r in arrivals}
        waiting: List[Tuple[float, float, int]] = []   # (ready, arrival, rid)
        held: List[int] = []                           # SLO "queue" pen
        next_tick: Dict[int, float] = {}               # replica -> t
        # requests that finished at prefill, completing when the clock
        # reaches ft: (ft, rid, replica, slot-state); the slot-state
        # identity check at fire time detects cancelled/re-dispatched
        # copies, so stale entries drain as no-ops
        pending_prefill: List[Tuple[float, int, int, Any]] = []
        completed: List[RouterCompleted] = []
        rejected: List[Dict[str, Any]] = []
        events: List[Dict[str, Any]] = []
        lat_window: List[float] = []
        counters = {"hedges": 0, "hedge_wins": 0, "timeouts": 0,
                    "retries": 0, "drained": 0}
        fault_events = list(self.fault_plan.events) if self.fault_plan else []
        arr_i = fault_i = 0
        rr_next = 0                                    # round-robin cursor
        t = 0.0
        done_count = 0
        total = len(arrivals)

        def reject(fl: _Flight, reason: str, now: float) -> None:
            nonlocal done_count
            fl.state = "rejected"
            rejected.append({"rid": fl.req.rid, "reason": reason,
                             "t": float(now)})
            events.append({"event": "reject", "rid": fl.req.rid,
                           "reason": reason, "t": float(now)})
            done_count += 1

        def observe(lat: float) -> None:
            lat_window.append(lat)
            if len(lat_window) > cfg.hedge_window:
                lat_window.pop(0)
            if slo is not None:
                slo.observe(lat)

        def pick_replica(req, exclude: int = -1) -> int:
            cands = [r for r in health.up_replicas()
                     if r != exclude and sessions[r].can_admit(req)]
            if not cands:
                return -1
            n = cfg.num_replicas
            return min(cands, key=lambda r: (sessions[r].n_active,
                                             (r - rr_next) % n))

        def untick(r: int) -> None:
            # a session emptied outside the tick loop (timeout, hedge
            # loser, prefill completion) must drop its pending tick, or a
            # later admission inherits a stale — possibly slowdown-
            # stretched — schedule
            if r >= 0 and not sessions[r].active:
                next_tick.pop(r, None)

        def complete(rid: int, winner: int, finish: float) -> None:
            nonlocal done_count
            fl = flights[rid]
            st = sessions[winner].release(rid)
            loser = fl.hedge if winner == fl.primary else fl.primary
            if loser >= 0 and rid in sessions[loser]._slot_of:
                sessions[loser].release(rid)       # cancel-and-free
            untick(winner)
            untick(loser)
            if winner == fl.hedge:
                counters["hedge_wins"] += 1
            fl.state = "done"
            done_count += 1
            completed.append(RouterCompleted(
                rid=rid, arrival=fl.req.arrival, admitted=st.admitted,
                first_token=st.first_token, finish=finish,
                prompt_len=fl.req.prompt_len, tokens=st.tokens,
                replica=winner, hedged=fl.was_hedged, retries=fl.retries,
                drains=fl.drains))
            observe(finish - fl.req.arrival)

        def admit_to(rid: int, r: int, now: float, *, hedge: bool) -> None:
            nonlocal rr_next
            fl = flights[rid]
            ft = now + cfg.prefill_time * health.factor(r, now)
            st = sessions[r].admit(fl.req, now, ft)
            rr_next = (r + 1) % cfg.num_replicas
            if hedge:
                fl.hedge = r
                fl.was_hedged = True
                counters["hedges"] += 1
                events.append({"event": "hedge", "rid": rid, "replica": r,
                               "t": float(now)})
                tracer.instant("router/hedge", rid=rid, replica=r,
                               vt=float(now))
            else:
                fl.primary, fl.state = r, "inflight"
                fl.dispatch_t = now
                fl.deadline = (now + cfg.timeout if cfg.timeout is not None
                               else float("inf"))
                tracer.instant("router/dispatch", rid=rid, replica=r,
                               vt=float(now))
            if sessions[r].done(st):               # finishes at prefill
                # completion is an *event at ft*, not a fact at admission:
                # the replica can still crash (or the copy be cancelled)
                # before the clock reaches ft, so schedule it instead of
                # completing in the past's future
                pending_prefill.append((ft, rid, r, st))
            else:
                base = next_tick.get(r)
                step = cfg.step_time * health.factor(r, now)
                if base is None:
                    next_tick[r] = ft + step
                else:                              # prefill defers the tick
                    next_tick[r] = base + cfg.prefill_time * \
                        health.factor(r, now)

        def drain(r: int, now: float, reason: str) -> None:
            for st in sessions[r].evict_all():
                rid = st.req.rid
                fl = flights[rid]
                if fl.state != "inflight":
                    continue
                other = fl.hedge if r == fl.primary else fl.primary
                if fl.hedge >= 0 and other >= 0 \
                        and rid in sessions[other]._slot_of:
                    # the surviving copy carries on as the new primary
                    fl.primary, fl.hedge = other, -1
                    continue
                fl.primary, fl.hedge = -1, -1
                fl.state = "waiting"
                fl.drains += 1
                counters["drained"] += 1
                waiting.append((now, fl.req.arrival, rid))
            next_tick.pop(r, None)
            events.append({"event": "drain", "replica": r, "t": float(now),
                           "reason": reason})
            tracer.instant("router/failover", replica=r, reason=reason,
                           vt=float(now))

        while done_count < total:
            # ---- phase A: drain everything due at time t --------------------
            changed = True
            while changed:
                changed = False
                health.expire(t)
                # faults
                while (fault_i < len(fault_events)
                       and fault_events[fault_i].step * cfg.step_time
                       <= t + 1e-12):
                    ev = fault_events[fault_i]
                    fault_i += 1
                    changed = True
                    r = ev.replica
                    if ev.kind == "crash" and health.is_up(r):
                        drain(r, t, "crash")
                        health.mark_down(r, t, reason="crash")
                    elif ev.kind == "preempt" and health.is_up(r):
                        drain(r, t, "preempt")
                        health.mark_down(
                            r, t, reason="preempt",
                            up_at=t + ev.duration * cfg.step_time)
                    elif ev.kind == "slowdown":
                        health.set_slowdown(
                            r, t, factor=ev.factor,
                            until=t + ev.duration * cfg.step_time)
                    elif ev.kind == "restart" and not health.is_up(r):
                        health.revive(r, t)
                # arrivals (the only path through the SLO gate)
                while arr_i < len(arrivals) \
                        and arrivals[arr_i].arrival <= t + 1e-12:
                    req = arrivals[arr_i]
                    arr_i += 1
                    changed = True
                    fl = flights[req.rid]
                    if cfg.max_queue is not None \
                            and len(waiting) >= cfg.max_queue:
                        reject(fl, "queue_overflow", t)
                        continue
                    verdict = slo.admit(t) if slo is not None else "admit"
                    if verdict == "shed":
                        reject(fl, "slo_shed", t)
                    elif verdict == "queue":
                        fl.state = "held"
                        held.append(req.rid)
                    else:
                        fl.state = "waiting"
                        waiting.append((req.arrival, req.arrival, req.rid))
                # SLO re-opened: release the hold pen
                if held and (slo is None or not slo.violating):
                    for rid in held:
                        flights[rid].state = "waiting"
                        waiting.append((t, flights[rid].req.arrival, rid))
                    held.clear()
                    changed = True
                elif held and not waiting and not next_tick:
                    # gate shut but the system is idle: nothing in flight
                    # means nothing can ever feed the estimator — probe
                    # with the oldest held request instead of deadlocking
                    rid = held.pop(0)
                    flights[rid].state = "waiting"
                    waiting.append((t, flights[rid].req.arrival, rid))
                    changed = True
                # prefill-only completions land when the clock reaches ft
                for entry in [p for p in pending_prefill
                              if p[0] <= t + 1e-12]:
                    pending_prefill.remove(entry)
                    _, rid, r, st = entry
                    slot = sessions[r]._slot_of.get(rid)
                    if slot is None or sessions[r].active.get(slot) is not st:
                        continue   # copy cancelled (drain/timeout/hedge win)
                    changed = True
                    complete(rid, r, t)
                # replica decode ticks — look up via .get(): complete()
                # above (and hedge-loser release inside it) may pop a
                # replica's entry while this sweep is mid-iteration
                for r in sorted(next_tick):
                    tick = next_tick.get(r)
                    if tick is None or tick > t + 1e-12:
                        continue
                    changed = True
                    for rid in sessions[r].tick():
                        complete(rid, r, t)
                    if sessions[r].active:
                        next_tick[r] = t + cfg.step_time * health.factor(r, t)
                    else:
                        next_tick.pop(r, None)
                # timeouts -> jittered capped exponential retry
                if cfg.timeout is not None:
                    for rid in sorted(flights):
                        fl = flights[rid]
                        if fl.state != "inflight" or fl.deadline > t + 1e-12:
                            continue
                        changed = True
                        for r in (fl.primary, fl.hedge):
                            if r >= 0 and rid in sessions[r]._slot_of:
                                sessions[r].release(rid)
                                untick(r)
                        counters["timeouts"] += 1
                        tracer.instant("router/timeout", rid=rid,
                                       vt=float(t))
                        if fl.retries >= cfg.max_retries:
                            reject(fl, "timeout", t)
                            continue
                        delay = min(cfg.backoff * 2.0 ** fl.retries,
                                    cfg.max_backoff) \
                            * (1.0 + cfg.jitter * float(rng.uniform()))
                        fl.retries += 1
                        counters["retries"] += 1
                        fl.primary, fl.hedge = -1, -1
                        fl.state = "waiting"
                        waiting.append((t + delay, fl.req.arrival, rid))
                        events.append({"event": "retry", "rid": rid,
                                       "t": float(t),
                                       "delay": float(delay)})
                # hedges: back up stragglers past the windowed percentile
                thresh = self._hedge_threshold(lat_window)
                if thresh is not None:
                    for rid in sorted(flights):
                        fl = flights[rid]
                        if (fl.state != "inflight" or fl.hedge >= 0
                                or t + 1e-12 < fl.dispatch_t + thresh):
                            continue
                        r = pick_replica(fl.req, exclude=fl.primary)
                        if r < 0:
                            continue
                        changed = True
                        admit_to(rid, r, t, hedge=True)
                # dispatch the waiting room in (arrival, rid) order
                ready = sorted([w for w in waiting if w[0] <= t + 1e-12],
                               key=lambda w: (w[1], w[2]))
                for entry in ready:
                    rid = entry[2]
                    fl = flights[rid]
                    if eng.pages_needed(fl.req) > eng.page_capacity:
                        waiting.remove(entry)
                        reject(fl, "pool_exhausted", t)
                        changed = True
                        continue
                    r = pick_replica(fl.req)
                    if r < 0:
                        continue
                    waiting.remove(entry)
                    changed = True
                    admit_to(rid, r, t, hedge=False)
            if done_count >= total:
                break
            # ---- phase B: advance to the next event -------------------------
            cands: List[float] = []
            if fault_i < len(fault_events):
                cands.append(fault_events[fault_i].step * cfg.step_time)
            if arr_i < len(arrivals):
                cands.append(arrivals[arr_i].arrival)
            cands.extend(w[0] for w in waiting if w[0] > t)
            cands.extend(next_tick.values())
            cands.extend(p[0] for p in pending_prefill)
            if cfg.timeout is not None:
                cands.extend(fl.deadline for fl in flights.values()
                             if fl.state == "inflight"
                             and fl.deadline > t)
            thresh = self._hedge_threshold(lat_window)
            if thresh is not None:
                cands.extend(fl.dispatch_t + thresh
                             for fl in flights.values()
                             if fl.state == "inflight" and fl.hedge < 0
                             and fl.dispatch_t + thresh > t)
            nr = health.next_restart()
            if nr != float("inf"):
                cands.append(nr)
            cands.extend(rep.slow_until for rep in health.replicas
                         if rep.state == "slow" and rep.slow_until > t)
            future = [c for c in cands if c > t + 1e-12]
            if not future:
                # nothing can ever run the rest: account for every request
                for _, _, rid in sorted(waiting, key=lambda w: (w[1], w[2])):
                    reject(flights[rid], "no_healthy_replica", t)
                waiting.clear()
                for rid in held:
                    reject(flights[rid], "no_healthy_replica", t)
                held.clear()
                for rid in sorted(flights):
                    if flights[rid].state == "pending":
                        reject(flights[rid], "no_healthy_replica", t)
                continue
            t = min(future)

        metrics = self._metrics(arrivals, completed, rejected, counters,
                                health, slo)
        if self.metrics is not None:
            reg = self.metrics
            reg.counter("router/completed").inc(len(completed))
            reg.counter("router/rejected").inc(len(rejected))
            for key in ("hedges", "hedge_wins", "timeouts", "retries",
                        "drained"):
                reg.counter(f"router/{key}").inc(counters[key])
            h = reg.histogram("router/latency")
            for c in completed:
                h.observe(c.latency)
        return RouterReport(completed=completed, rejected=rejected,
                            metrics=metrics, events=events,
                            health=list(health.log))

    # -- metrics --------------------------------------------------------------

    def _metrics(self, arrivals, completed, rejected, counters, health,
                 slo) -> Dict[str, float]:
        lats = np.array([c.latency for c in completed] or [0.0])
        ttfts = np.array([c.ttft for c in completed] or [0.0])
        t_end = max([c.finish for c in completed]
                    + [r["t"] for r in rejected] + [0.0])
        t_start = min((r.arrival for r in arrivals), default=0.0)
        duration = max(t_end - t_start, 1e-9)
        total = len(arrivals)
        m = {
            "total": total,
            "completed": len(completed),
            "rejected": len(rejected),
            "lost_requests": total - len(completed) - len(rejected),
            "duration": duration,
            "goodput": len(completed) / duration,
            "p50_latency": float(np.percentile(lats, 50)),
            "p99_latency": float(np.percentile(lats, 99)),
            "p99_ttft": float(np.percentile(ttfts, 99)),
            "hedges": counters["hedges"],
            "hedge_wins": counters["hedge_wins"],
            "timeouts": counters["timeouts"],
            "retries": counters["retries"],
            "drained": counters["drained"],
            "shed": sum(1 for r in rejected if r["reason"] == "slo_shed"),
        }
        m.update(health.counts())
        if slo is not None:
            m["slo_trips"] = slo.trips
            m["slo_reentered"] = int(slo.trips > 0 and not slo.violating)
        return m
