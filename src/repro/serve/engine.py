"""The serve engine: continuous batching over the paged KV cache.

One engine owns two jitted functions (``paged_model``): a bucketed prefill
(compiled once per power-of-two prompt bucket) and a single decode step
over all ``num_slots`` decode slots (compiled once). The host loop is the
scheduler: it admits requests from the open-loop arrival queue whenever a
slot AND enough pool pages are free (continuous batching), or only when
the whole batch has drained (``policy="static"``, the toy baseline), and
evicts at decode-step granularity — on completion, and under the chaos
engine's ``preempt`` fault, which throws every in-flight request back to
the queue (recomputed on readmission; greedy decode makes the retry
token-identical, so preemption costs latency, never correctness).

Two clocks: ``"wall"`` (real seconds — the benchmark path; chaos
slowdowns stretch each decode step by sleeping the residual) and
``"virtual"`` (deterministic units per step — the test path, where p99
assertions must not depend on host speed).

``restore_params`` is the checkpoint→serve bridge: it pulls just the
``params`` (or ``ema``) subtree of a training checkpoint through
``train/checkpoint.py``'s verified restore — replicated, TP-sharded and
sim checkpoints are all stored gathered, so one template fits all three.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_lib
from repro.models import get_model
from repro.obs.trace import as_tracer
from repro.serve import pages as pages_lib
from repro.serve import trace as trace_lib
from repro.serve.paged_model import (build_paged_decode, build_paged_prefill,
                                     build_tp_paged_fns, supports_paged)

SERVE_FAULT_KINDS = ("slowdown", "preempt")
SERVE_POLICIES = ("continuous", "static")


@dataclasses.dataclass
class CompletedRequest:
    rid: int
    arrival: float
    admitted: float
    first_token: float
    finish: float
    prompt_len: int
    tokens: List[int]
    preemptions: int = 0

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival


@dataclasses.dataclass
class ServeReport:
    policy: str
    completed: List[CompletedRequest]
    metrics: Dict[str, float]
    events: List[Dict[str, Any]]
    # graceful-degradation records: requests the engine refused instead of
    # wedging on — each entry {"rid", "reason", "t"} (reasons:
    # "queue_overflow", "pool_exhausted")
    rejected: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def tokens_by_rid(self) -> Dict[int, List[int]]:
        return {c.rid: list(c.tokens) for c in self.completed}


class _Slot:
    __slots__ = ("req", "admitted", "first_token", "tokens", "last_token",
                 "length", "produced", "preemptions")

    def __init__(self, req, admitted, first_token, first_tok_id, preemptions):
        self.req = req
        self.admitted = admitted
        self.first_token = first_token
        self.tokens = [first_tok_id]
        self.last_token = first_tok_id
        self.length = req.prompt_len      # positions with K/V written
        self.produced = 1                 # prefill samples the first token
        self.preemptions = preemptions


class ServeEngine:
    """Continuous-batching inference over a paged, optionally int8, pool."""

    def __init__(self, model_cfg, params, *, num_slots: int = 4,
                 page_size: int = 8, max_prompt_len: int = 32,
                 max_new_cap: int = 32, num_pages: Optional[int] = None,
                 cache_int8: bool = False, mesh_model: int = 1,
                 use_kernel: bool = False, interpret: Optional[bool] = None,
                 clock: str = "wall", step_time: float = 1.0,
                 prefill_time: float = 1.0, faults: Optional[str] = None,
                 fault_horizon: int = 256, fault_seed: int = 0,
                 eos_id: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 strict_capacity: bool = True,
                 slo=None, tracer=None, metrics=None):
        ok, why = supports_paged(model_cfg)
        if not ok:
            raise ValueError(f"paged serving unsupported: {why}")
        if clock not in ("wall", "virtual"):
            raise ValueError(f"clock must be 'wall' or 'virtual' (got {clock})")
        from repro.distributed.spmd_engine import _auto_interpret
        self.cfg = model_cfg
        self.model = get_model(model_cfg)
        # engine-level SLO admission (serve/slo.py SLOConfig): under
        # clock='wall' the gate controls on *measured* request latency —
        # the wall-clock SLO loop; under clock='virtual' it stays
        # replay-deterministic. tracer/metrics are pure observability.
        self.slo_cfg = slo
        self.tracer = as_tracer(tracer)
        self.registry = metrics
        self._prefill_s = 0.0
        self._decode_s = 0.0
        self.clock = clock
        self.step_time = step_time
        self.prefill_time = prefill_time
        self.eos_id = eos_id
        self.page_size = page_size
        self.max_queue = max_queue
        self.max_bucket = trace_lib.bucket_for(max_prompt_len,
                                               floor=page_size, cap=1 << 30)
        self.max_new_cap = max_new_cap
        max_pages = pages_lib.pages_for(self.max_bucket + max_new_cap,
                                        page_size)
        if num_pages is None:
            num_pages = num_slots * max_pages + 1
        if strict_capacity and num_pages - 1 < max_pages:
            # strict (default): every request the caps admit must fit an
            # idle pool. strict_capacity=False permits deliberately
            # undersized pools — unfittable requests are then *rejected*
            # with a structured reason at admission, never wedged on.
            raise ValueError(
                f"num_pages={num_pages} cannot hold even one request "
                f"({max_pages} pages + the trash page); pass "
                f"strict_capacity=False to degrade to rejection instead")
        self.pool_cfg = pages_lib.PoolConfig(
            num_layers=model_cfg.num_layers,
            kv_heads=model_cfg.num_kv_heads,
            head_dim=model_cfg.resolved_head_dim,
            num_pages=num_pages, page_size=page_size, num_slots=num_slots,
            max_pages_per_slot=max_pages, quantized=cache_int8)
        interp = _auto_interpret(interpret)
        self.mesh_model = mesh_model
        if mesh_model > 1:
            from repro.launch.mesh import make_host_mesh
            if mesh_model > jax.device_count():
                raise ValueError(
                    f"mesh_model={mesh_model} needs {mesh_model} devices "
                    f"but only {jax.device_count()} present (force host "
                    f"devices with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N)")
            self.mesh = make_host_mesh(1, mesh_model)
            template = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
            prefill, decode, plan, param_sh, pool_sh = build_tp_paged_fns(
                model_cfg, self.mesh, template, quantized=cache_int8,
                use_kernel=use_kernel, interpret=interp)
            self.params = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                params, param_sh)
            self.tp_plan = plan
            self._pool_shardings = pool_sh
        else:
            decode = build_paged_decode(self.model, quantized=cache_int8,
                                        use_kernel=use_kernel,
                                        interpret=interp)
            prefill = build_paged_prefill(self.model, quantized=cache_int8)
            self.params = jax.tree_util.tree_map(jnp.asarray, params)
            self.tp_plan = None
            self._pool_shardings = None
        self._decode = jax.jit(decode)
        self._prefill = jax.jit(prefill)
        self.fault_plan = None
        if faults:
            plan_f = faults_lib.plan_from_spec(
                faults, num_steps=fault_horizon, num_workers=num_slots,
                seed=fault_seed)
            bad = sorted({e.kind for e in plan_f.events
                          if e.kind not in SERVE_FAULT_KINDS})
            if bad:
                raise ValueError(
                    f"serve wires only {SERVE_FAULT_KINDS} of the fault "
                    f"taxonomy (decode is lockstep — no per-worker crash/"
                    f"restart/ckpt_io surface); got {bad}")
            self.fault_plan = plan_f

    # -- compile counters (the bucket contract) -------------------------------

    @property
    def prefill_compiles(self) -> int:
        return int(self._prefill._cache_size())

    @property
    def decode_compiles(self) -> int:
        return int(self._decode._cache_size())

    # -- clock ----------------------------------------------------------------

    def _now(self) -> float:
        if self.clock == "wall":
            return time.perf_counter() - self._t0
        return self._vnow

    def _advance_to(self, t: float) -> None:
        if self.clock == "wall":
            dt = t - self._now()
            if dt > 0:
                time.sleep(dt)
        else:
            self._vnow = max(self._vnow, t)

    def _advance_decode(self, elapsed: float, factor: float) -> None:
        if self.clock == "wall":
            extra = elapsed * (factor - 1.0)
            if extra > 0:
                time.sleep(extra)
        else:
            self._vnow += self.step_time * factor

    def _advance_prefill(self, elapsed: float) -> None:
        if self.clock == "virtual":
            self._vnow += self.prefill_time

    # -- request geometry ------------------------------------------------------

    def validate_request(self, r: trace_lib.Request) -> None:
        if r.prompt_len > self.max_bucket:
            raise ValueError(f"request {r.rid}: prompt_len "
                             f"{r.prompt_len} > bucket cap "
                             f"{self.max_bucket}")
        if not 1 <= r.max_new <= self.max_new_cap:
            raise ValueError(f"request {r.rid}: max_new {r.max_new} "
                             f"outside [1, {self.max_new_cap}]")

    def pages_needed(self, req: trace_lib.Request) -> int:
        """Pages a request holds for its whole lifetime: the prefill
        scatter needs the full bucket, the decode tail the rest."""
        return max(
            trace_lib.bucket_for(req.prompt_len, floor=self.page_size,
                                 cap=self.max_bucket) // self.page_size,
            pages_lib.pages_for(req.prompt_len + req.max_new,
                                self.page_size))

    @property
    def page_capacity(self) -> int:
        """Most pages any single request can ever be granted."""
        return min(self.pool_cfg.num_pages - 1,
                   self.pool_cfg.max_pages_per_slot)

    # -- the serving loop -----------------------------------------------------

    def run(self, trace: Sequence[trace_lib.Request],
            policy: str = "continuous") -> ServeReport:
        if policy not in SERVE_POLICIES:
            raise ValueError(f"policy must be one of {SERVE_POLICIES}")
        for r in trace:
            self.validate_request(r)
        pool = pages_lib.PagePool(self.pool_cfg, dtype=self.model.dtype,
                                  shardings=self._pool_shardings)
        self._bufs = pool.buffers
        pending = collections.deque(
            sorted(trace, key=lambda r: (r.arrival, r.rid)))
        queue: collections.deque = collections.deque()
        active: Dict[int, _Slot] = {}
        free_slots = list(range(self.pool_cfg.num_slots - 1, -1, -1))
        completed: List[CompletedRequest] = []
        events: List[Dict[str, Any]] = []
        rejected: List[Dict[str, Any]] = []
        preempt_counts: Dict[int, int] = {}
        from repro.serve.slo import SLOController
        slo = SLOController(self.slo_cfg) if self.slo_cfg else None
        held: List[trace_lib.Request] = []     # SLO "queue" holding pen
        self._prefill_s = 0.0
        self._decode_s = 0.0
        wall_t0 = time.perf_counter()
        self._t0 = time.perf_counter()
        self._vnow = 0.0
        step_idx = 0
        slow_factor, slow_until = 1.0, -1

        def complete(slot: int, st: _Slot, now: float) -> None:
            if slo is not None:
                slo.observe(now - st.req.arrival)
            pool.free_slot(slot)
            free_slots.append(slot)
            completed.append(CompletedRequest(
                rid=st.req.rid, arrival=st.req.arrival, admitted=st.admitted,
                first_token=st.first_token, finish=now,
                prompt_len=st.req.prompt_len, tokens=st.tokens,
                preemptions=st.preemptions))

        def reject(req: trace_lib.Request, reason: str, now: float) -> None:
            rejected.append({"rid": req.rid, "reason": reason,
                             "t": float(now)})
            events.append({"event": "reject", "rid": req.rid,
                           "reason": reason, "step": step_idx})

        while pending or queue or active or held:
            now = self._now()
            while pending and pending[0].arrival <= now:
                req = pending.popleft()
                if (self.max_queue is not None
                        and len(queue) >= self.max_queue):
                    # admission-queue overflow: shed at the door with a
                    # structured reason (requeued preemptions bypass this
                    # — they re-enter at the queue head, never shed)
                    reject(req, "queue_overflow", now)
                    continue
                verdict = slo.admit(now) if slo is not None else "admit"
                if verdict == "shed":
                    reject(req, "slo_shed", now)
                elif verdict == "queue":
                    held.append(req)
                else:
                    queue.append(req)
            if held and not slo.violating:
                # gate re-opened under hysteresis: release the pen in
                # arrival order behind whatever is already queued
                queue.extend(held)
                held.clear()
            elif held and not queue and not active and not pending:
                # gate shut but the engine is idle: nothing in flight can
                # ever feed the estimator — probe with the oldest held
                # request instead of deadlocking
                queue.append(held.pop(0))
            # -- admission ---------------------------------------------------
            may_admit = bool(queue) and (policy == "continuous"
                                         or not active)
            while may_admit and queue and free_slots:
                req = queue[0]
                need = self.pages_needed(req)
                if need > self.page_capacity:
                    # can never fit, even into an idle pool (undersized
                    # strict_capacity=False pools): degrade to rejection
                    queue.popleft()
                    reject(req, "pool_exhausted", now)
                    continue
                if not pool.can_alloc(need):
                    break
                queue.popleft()
                slot = free_slots.pop()
                st = self._admit(req, slot, need, pool,
                                 preempt_counts.get(req.rid, 0))
                if st.produced >= req.max_new or (
                        self.eos_id is not None
                        and st.last_token == self.eos_id):
                    complete(slot, st, self._now())
                else:
                    active[slot] = st
            if not active:
                if pending:
                    self._advance_to(pending[0].arrival)
                    continue
                if queue:          # pool can hold any valid request when idle
                    raise RuntimeError("scheduler wedged: empty slots but "
                                       "queue not admissible")
                continue
            # -- chaos at decode-step granularity ----------------------------
            if self.fault_plan:
                for ev in self.fault_plan.events:
                    if ev.step != step_idx:
                        continue
                    if ev.kind == "slowdown":
                        slow_factor, slow_until = ev.factor, \
                            step_idx + ev.duration
                        events.append({"event": "slowdown", "step": step_idx,
                                       "factor": ev.factor,
                                       "duration": ev.duration})
                    elif ev.kind == "preempt":
                        evicted = sorted(active.items())
                        for slot, st in evicted:
                            pool.free_slot(slot)
                            free_slots.append(slot)
                            preempt_counts[st.req.rid] = st.preemptions + 1
                        active.clear()
                        for _, st in reversed(evicted):
                            queue.appendleft(st.req)
                        events.append({"event": "preempt", "step": step_idx,
                                       "evicted": len(evicted)})
                        self.tracer.instant("serve/evict", step=step_idx,
                                            evicted=len(evicted))
                if not active:
                    step_idx += 1
                    continue
            factor = slow_factor if step_idx <= slow_until else 1.0
            # -- one decode step over every slot -----------------------------
            # [last_token, len, *page_table_row] per slot, one transfer:
            # at smoke scale the loop is host-dispatch-bound, so the packed
            # state (and the in-graph argmax) is what makes continuous
            # batching's fewer-steps advantage show up in wall clock.
            n_slots = self.pool_cfg.num_slots
            state = np.zeros((n_slots, 2 + self.pool_cfg.max_pages_per_slot),
                             np.int32)
            for slot, st in active.items():
                state[slot, 0] = st.last_token
                state[slot, 1] = st.length
            state[:, 2:] = pool.page_table
            t_start = time.perf_counter()
            with self.tracer.span("serve/decode", step=step_idx,
                                  n_active=len(active)):
                toks_dev, self._bufs = self._decode(self.params, state,
                                                    self._bufs)
                next_tokens = np.asarray(toks_dev)
            dt = time.perf_counter() - t_start
            self._decode_s += dt
            if self.registry is not None:
                self.registry.histogram("serve/decode_s").observe(dt)
            self._advance_decode(dt, factor)
            pool.note_occupancy()
            now = self._now()
            for slot in sorted(active):
                st = active[slot]
                st.length += 1
                tok = int(next_tokens[slot])
                st.tokens.append(tok)
                st.last_token = tok
                st.produced += 1
                if st.produced >= st.req.max_new or (
                        self.eos_id is not None and tok == self.eos_id):
                    del active[slot]
                    complete(slot, st, now)
            step_idx += 1

        metrics = self._metrics(trace, completed, pool, step_idx, events,
                                rejected=rejected)
        metrics["wall_time_s"] = time.perf_counter() - wall_t0
        metrics["prefill_s"] = self._prefill_s
        metrics["decode_s"] = self._decode_s
        metrics["rejected_slo_shed"] = sum(
            1 for r in rejected if r["reason"] == "slo_shed")
        if slo is not None:
            metrics["slo_trips"] = slo.trips
            metrics["slo_estimate"] = slo.estimate()
        if self.registry is not None:
            reg = self.registry
            reg.counter("serve/completed").inc(len(completed))
            reg.counter("serve/rejected").inc(len(rejected))
            reg.counter("serve/slo_shed").inc(
                metrics["rejected_slo_shed"])
            reg.counter("serve/tokens").inc(
                sum(len(c.tokens) for c in completed))
            hl = reg.histogram("serve/latency")
            ht = reg.histogram("serve/ttft")
            for c in completed:
                hl.observe(c.latency)
                ht.observe(c.ttft)
            reg.gauge("serve/wall_time_s").set(metrics["wall_time_s"])
        return ServeReport(policy=policy, completed=completed,
                           metrics=metrics,
                           events=events, rejected=rejected)

    def _admit(self, req, slot: int, need: int, pool: pages_lib.PagePool,
               preemptions: int) -> _Slot:
        pool.alloc(slot, need)
        bucket = trace_lib.bucket_for(req.prompt_len, floor=self.page_size,
                                      cap=self.max_bucket)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :req.prompt_len] = req.prompt
        meta = np.empty((1 + bucket // self.page_size,), np.int32)
        meta[0] = req.prompt_len
        meta[1:] = pool.page_table[slot, :bucket // self.page_size]
        admitted = self._now()
        with self.tracer.span("serve/admit", rid=req.rid):
            t_start = time.perf_counter()
            with self.tracer.span("serve/prefill", rid=req.rid,
                                  prompt_len=req.prompt_len):
                tok_dev, self._bufs = self._prefill(self.params, tokens,
                                                    meta, self._bufs)
                first_tok = int(np.asarray(tok_dev))
            dt = time.perf_counter() - t_start
        self._prefill_s += dt
        if self.registry is not None:
            self.registry.histogram("serve/prefill_s").observe(dt)
        self._advance_prefill(dt)
        return _Slot(req, admitted, self._now(), first_tok, preemptions)

    def _metrics(self, trace, completed, pool, decode_steps, events,
                 rejected=()):
        lats = np.array([c.latency for c in completed] or [0.0])
        ttfts = np.array([c.ttft for c in completed] or [0.0])
        total_tokens = sum(len(c.tokens) for c in completed)
        t_end = max((c.finish for c in completed), default=0.0)
        t_start = min((r.arrival for r in trace), default=0.0)
        duration = max(t_end - t_start, 1e-9)
        return {
            "completed": len(completed),
            "total_tokens": total_tokens,
            "duration": duration,
            "tokens_per_s": total_tokens / duration,
            "p50_latency": float(np.percentile(lats, 50)),
            "p99_latency": float(np.percentile(lats, 99)),
            "p50_ttft": float(np.percentile(ttfts, 50)),
            "p99_ttft": float(np.percentile(ttfts, 99)),
            "mean_occupancy": pool.mean_occupancy(),
            "peak_pages": pool.peak_pages,
            "decode_steps": decode_steps,
            "preemptions": sum(1 for e in events if e["event"] == "preempt"),
            "prefill_compiles": self.prefill_compiles,
            "decode_compiles": self.decode_compiles,
            "rejected": len(rejected),
            "rejected_queue_overflow": sum(
                1 for r in rejected if r["reason"] == "queue_overflow"),
            "rejected_pool_exhausted": sum(
                1 for r in rejected if r["reason"] == "pool_exhausted"),
        }


# ---------------------------------------------------------------------------
# Incremental per-replica surface (the router drives R of these)
# ---------------------------------------------------------------------------


class StepSession:
    """One serving replica as an incremental admit/tick surface.

    Sessions share a single engine's jitted prefill/decode and weights —
    they are R production replicas of one server build — but each owns
    its KV pool, page table and decode slots, so replicas fail and drain
    independently. The *caller* owns all timekeeping: ``admit`` takes
    explicit timestamps and ``tick`` only reports which requests finished,
    so the router's virtual clock fully determines every report and
    same-seed replays are bit-identical (ISSUE 8 tentpole contract).
    Greedy decode makes a request's token stream identical no matter
    which replica (or how many hedged copies) ran it.
    """

    def __init__(self, engine: ServeEngine, name: str = ""):
        self.engine = engine
        self.name = name
        self.pool = pages_lib.PagePool(engine.pool_cfg,
                                       dtype=engine.model.dtype,
                                       shardings=engine._pool_shardings)
        self._bufs = self.pool.buffers
        self.free_slots = list(range(engine.pool_cfg.num_slots - 1, -1, -1))
        self.active: Dict[int, _Slot] = {}
        self._slot_of: Dict[int, int] = {}

    @property
    def n_active(self) -> int:
        return len(self.active)

    def can_admit(self, req: trace_lib.Request) -> bool:
        need = self.engine.pages_needed(req)
        return (bool(self.free_slots) and need <= self.engine.page_capacity
                and self.pool.can_alloc(need))

    def admit(self, req: trace_lib.Request, admitted_t: float,
              first_token_t: float, preemptions: int = 0) -> _Slot:
        """Prefill ``req`` into a free slot (caller checked ``can_admit``
        and stamps both times). The returned slot state may already be
        ``done()`` — single-token requests finish at prefill."""
        need = self.engine.pages_needed(req)
        slot = self.free_slots.pop()
        self.pool.alloc(slot, need)
        eng = self.engine
        bucket = trace_lib.bucket_for(req.prompt_len, floor=eng.page_size,
                                      cap=eng.max_bucket)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :req.prompt_len] = req.prompt
        meta = np.empty((1 + bucket // eng.page_size,), np.int32)
        meta[0] = req.prompt_len
        meta[1:] = self.pool.page_table[slot, :bucket // eng.page_size]
        with eng.tracer.span("serve/prefill", rid=req.rid,
                             replica=self.name):
            tok_dev, self._bufs = eng._prefill(eng.params, tokens, meta,
                                               self._bufs)
        st = _Slot(req, admitted_t, first_token_t, int(np.asarray(tok_dev)),
                   preemptions)
        self.active[slot] = st
        self._slot_of[req.rid] = slot
        return st

    def done(self, st: _Slot) -> bool:
        return st.produced >= st.req.max_new or (
            self.engine.eos_id is not None
            and st.last_token == self.engine.eos_id)

    def release(self, rid: int) -> _Slot:
        """Free ``rid``'s slot and pages — completion, a hedge loser being
        cancelled, or an unhealthy replica draining. Returns the slot
        state so the caller can keep (or drop) its tokens."""
        slot = self._slot_of.pop(rid)
        st = self.active.pop(slot)
        self.pool.free_slot(slot)
        self.free_slots.append(slot)
        return st

    def evict_all(self) -> List[_Slot]:
        """Crash/preempt: drop every in-flight request, freeing all pages.
        Returns slot states in slot order for deterministic requeue."""
        sts = [st for _, st in sorted(self.active.items())]
        for slot in list(self.active):
            self.pool.free_slot(slot)
            self.free_slots.append(slot)
        self.active.clear()
        self._slot_of.clear()
        return sts

    def tick(self) -> List[int]:
        """One decode step over every active slot (one token each).
        Returns the rids that finished this step; the caller stamps their
        finish time and calls :meth:`release`."""
        if not self.active:
            return []
        eng = self.engine
        n_slots = eng.pool_cfg.num_slots
        state = np.zeros((n_slots, 2 + eng.pool_cfg.max_pages_per_slot),
                         np.int32)
        for slot, st in self.active.items():
            if self.done(st):
                continue   # finished at prefill; holds its slot until the
                           # caller's scheduled release — never decodes
            state[slot, 0] = st.last_token
            state[slot, 1] = st.length
        state[:, 2:] = self.pool.page_table
        with eng.tracer.span("serve/decode", replica=self.name,
                             n_active=len(self.active)):
            toks_dev, self._bufs = eng._decode(eng.params, state, self._bufs)
        next_tokens = np.asarray(toks_dev)
        finished: List[int] = []
        for slot in sorted(self.active):
            st = self.active[slot]
            if self.done(st):
                continue
            st.length += 1
            tok = int(next_tokens[slot])
            st.tokens.append(tok)
            st.last_token = tok
            st.produced += 1
            if self.done(st):
                finished.append(st.req.rid)
        self.pool.note_occupancy()
        return finished


# ---------------------------------------------------------------------------
# Checkpoint -> serve bridge
# ---------------------------------------------------------------------------


def restore_params(directory: str, model_cfg, *, step: Optional[int] = None,
                   use_ema: bool = False):
    """Load just the weights of a training checkpoint for serving.

    Checkpoints are stored gathered (full shapes) by every backend — sim,
    replicated SPMD, and TP-sharded alike (PR 5's interchangeability
    contract) — so a single eval_shape template restores all three; the
    engine re-shards on admission when ``mesh_model > 1``. Goes through
    ``checkpoint.restore``'s CRC-verified, walk-back path. Returns
    ``(params, manifest)``.
    """
    from repro.train import checkpoint as ckpt_lib
    model = get_model(model_cfg)
    template = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    key = "ema" if use_ema else "params"
    tree, manifest = ckpt_lib.restore(directory, {key: template}, step)
    return tree[key], manifest
