"""The paper's headline comparison (Figs. 8/9) at laptop scale:
Async-Opt vs plain Sync-Opt vs Sync-Opt with backup workers (plus the
SoftSync related-work baseline), identical machine budget, simulated
cluster latencies. Every variant runs through the single
``run_experiment(cfg)`` entry point — only the strategy string changes.

    PYTHONPATH=src python examples/sync_vs_async.py [--steps 250]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()
    os.environ.setdefault("REPRO_BENCH_FULL", "0")

    from benchmarks import bench_sync_vs_async
    rows = bench_sync_vs_async.run(quick=args.steps <= 250, steps=args.steps)
    print(f"{'variant':<45} | result")
    print("-" * 70)
    for name, us, derived in rows:
        print(f"{name:<45} | {derived}")
    print("\nArtifacts: experiments/bench/sync_vs_async.json "
          "(full loss/time trajectories).")


if __name__ == "__main__":
    main()
