"""Paper §2.1 reproduction: train the 4-layer weight-normalized CNN with
simulated gradient staleness (old-gradient buffer + ramp-up trick) and
watch the test error degrade as staleness grows — Fig. 2's shape.

Routes through ``run_experiment(cfg)`` with ``strategy='staleness'``:
the MNIST CNN and its batch source plug in via the ``model``/``batch_fn``
overrides, and the run gains EMA and the unified metrics schema for free.

    PYTHONPATH=src python examples/staleness_mnist.py [--steps 600] \
        [--staleness 0 10 25 50]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ModelConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.data import mnist_like
from repro.models import mnist_cnn
from repro.train.loop import run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--staleness", type=int, nargs="+", default=[0, 10, 25])
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    data_cfg = mnist_like.MnistLikeConfig(num_train=4096, num_test=1024)
    train, test = mnist_like.make_dataset(data_cfg)
    model = mnist_cnn.make(widths=(16, 16, 32, 32))

    def batch_fn(worker: int, draw: int):
        rng = np.random.RandomState(draw)
        idx = rng.randint(0, data_cfg.num_train, size=args.batch)
        return {"images": jnp.asarray(train["images"][idx]),
                "labels": jnp.asarray(train["labels"][idx])}

    print(f"{'staleness':>9} | {'test err':>8} | {'mean tau':>8} | secs")
    print("-" * 44)
    for tau in args.staleness:
        t0 = time.time()
        cfg = TrainConfig(
            model=ModelConfig(name="mnist_cnn"),   # overridden below
            shape=ShapeConfig("mnist", 1, args.batch, "train"),
            aggregation=AggregationConfig(
                strategy="staleness", num_workers=1, staleness_tau=tau,
                staleness_ramp_steps=max(1, args.steps // 5)),
            optimizer=OptimizerConfig(name="sgd", learning_rate=args.lr,
                                      scale_lr_with_workers=False,
                                      ema_decay=0.999,
                                      linear_anneal_steps=args.steps,
                                      linear_anneal_from=int(args.steps
                                                             * 0.6)),
            checkpoint=CheckpointConfig(every_steps=0),
            seed=0, total_steps=args.steps, log_every=args.steps)
        res = run_experiment(cfg, model=model, batch_fn=batch_fn)
        logits = model.forward(res.ema, jnp.asarray(test["images"]))
        err = float((np.asarray(jnp.argmax(logits, -1))
                     != test["labels"]).mean())
        print(f"{tau:9d} | {err:8.4f} | {res.mean_staleness:8.1f} | "
              f"{time.time() - t0:.0f}")
    print("\npaper (real MNIST, 25 epochs): 0.36% @ tau=0, 0.47% @ 20, "
          "0.79% @ 50 — same monotone shape.")


if __name__ == "__main__":
    main()
