"""Paper §2.1 reproduction: train the 4-layer weight-normalized CNN with
simulated gradient staleness (old-gradient buffer + ramp-up trick) and
watch the test error degrade as staleness grows — Fig. 2's shape.

    PYTHONPATH=src python examples/staleness_mnist.py [--steps 600] \
        [--staleness 0 10 25 50]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import async_sim
from repro.data import mnist_like
from repro.models import mnist_cnn
from repro.optim import schedules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--staleness", type=int, nargs="+", default=[0, 10, 25])
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    data_cfg = mnist_like.MnistLikeConfig(num_train=4096, num_test=1024)
    train, test = mnist_like.make_dataset(data_cfg)
    model = mnist_cnn.make(widths=(16, 16, 32, 32))
    sched = schedules.linear_anneal(args.lr, args.steps,
                                    int(args.steps * 0.6))

    @jax.jit
    def grad_fn(params, batch):
        def loss(p):
            return model.per_example_loss(p, batch).mean()
        return jax.value_and_grad(loss)(params)

    def update_fn(params, opt_state, grads, step):
        lr = sched(jnp.asarray(step))
        return jax.tree_util.tree_map(lambda p, g: p - lr * g, params,
                                      grads), opt_state

    def batch_fn(step):
        rng = np.random.RandomState(step)
        idx = rng.randint(0, data_cfg.num_train, size=args.batch)
        return {"images": jnp.asarray(train["images"][idx]),
                "labels": jnp.asarray(train["labels"][idx])}

    print(f"{'staleness':>9} | {'test err':>8} | {'mean tau':>8} | secs")
    print("-" * 44)
    for tau in args.staleness:
        t0 = time.time()
        params0 = model.init(jax.random.PRNGKey(0))
        res = async_sim.simulate_staleness(
            grad_fn, update_fn, params0, batch_fn, num_updates=args.steps,
            staleness=tau, ramp_steps=max(1, args.steps // 5),
            ema_decay=0.999)
        logits = model.forward(res.ema, jnp.asarray(test["images"]))
        err = float((np.asarray(jnp.argmax(logits, -1))
                     != test["labels"]).mean())
        print(f"{tau:9d} | {err:8.4f} | {res.staleness.mean():8.1f} | "
              f"{time.time() - t0:.0f}")
    print("\npaper (real MNIST, 25 epochs): 0.36% @ tau=0, 0.47% @ 20, "
          "0.79% @ 50 — same monotone shape.")


if __name__ == "__main__":
    main()
