"""End-to-end training driver: full pipeline (data -> masked sync-backup
aggregation -> RMSProp+momentum -> EMA -> checkpoints -> elastic restart)
on a real multi-layer transformer.

Presets:
  tiny  (~3M params,  default)  — seconds/step on this CPU container
  25m   (~25M params)           — a few hundred steps feasible on CPU
  100m  (~114M params)          — the deliverable-scale run; on CPU expect
                                  ~1 min/step at batch 32x256; on a real
                                  pod this is the config you'd launch

    PYTHONPATH=src python examples/train_e2e.py --preset tiny --steps 100
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 5
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ModelConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core.straggler import PaperCalibrated
from repro.models import registry
from repro.train.loop import Trainer

PRESETS = {
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 head_dim=32, d_ff=512, vocab_size=2048, seq=64, batch=16),
    "25m": dict(num_layers=8, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1536, vocab_size=16384, seq=128, batch=16),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768, seq=256, batch=32),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--backups", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-worker-at", type=int, default=0,
                    help="inject a worker failure at this step (0=off)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    model_cfg = ModelConfig(
        name=f"e2e-{args.preset}", family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        vocab_pad_multiple=128, dtype="float32", remat="none",
        qk_norm=True, tie_embeddings=True)
    cfg = TrainConfig(
        model=model_cfg,
        shape=ShapeConfig("e2e", p["seq"],
                          p["batch"] * (args.workers + args.backups),
                          "train"),
        aggregation=AggregationConfig(strategy="backup",
                                      num_workers=args.workers,
                                      backup_workers=args.backups),
        optimizer=OptimizerConfig(name="rmsprop_momentum",
                                  learning_rate=2e-4 * args.workers,
                                  scale_lr_with_workers=False,
                                  decay=0.9, momentum=0.9,
                                  lr_decay_rate=0.94, steps_per_epoch=100,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(directory=args.ckpt_dir, every_steps=50),
        log_every=10)

    print(f"preset={args.preset}: "
          f"{registry.param_count(model_cfg) / 1e6:.1f}M params, "
          f"global batch {cfg.shape.global_batch} x seq {cfg.shape.seq_len}, "
          f"N={args.workers} b={args.backups}")
    tr = Trainer(cfg, latency=PaperCalibrated())
    if args.resume and os.path.exists(os.path.join(args.ckpt_dir, "LATEST")):
        tr.restore_checkpoint()
        print(f"resumed from step {tr.step}")
    else:
        tr.init_state()

    kills = ({args.kill_worker_at: 0} if args.kill_worker_at else None)
    t0 = time.time()
    res = tr.run(args.steps, kill_worker_at=kills)
    wall = time.time() - t0
    for m in res.metrics:
        print(f"  step {m['step']:5d} loss {m['loss']:.4f} "
              f"lr {m.get('lr', 0):.2e} sim {m['sim_time']:8.1f}s "
              f"sel {m['selected']}")
    toks = cfg.shape.global_batch * cfg.shape.seq_len * args.steps
    print(f"\n{args.steps} steps in {wall:.0f}s wall "
          f"({toks / wall:.0f} tok/s host), simulated cluster time "
          f"{res.sim_time:.0f}s, restarts={res.restarts}")
    tr.save_checkpoint()
    print(f"checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
