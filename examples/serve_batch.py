"""Serving example: batched prefill + greedy decode with KV caches on a
smoke-scale model of any assigned architecture.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-1b --tokens 12
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import get_model
from repro.train.serve_step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=configs.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["encoder_frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq_len, cfg.d_model))

    t0 = time.time()
    out = greedy_generate(model, params, prompt, num_tokens=args.tokens,
                          max_len=args.prompt_len + args.tokens + 1, **kwargs)
    wall = time.time() - t0
    print(f"arch={args.arch} ({cfg.name}): generated "
          f"{args.batch}x{args.tokens} tokens in {wall:.1f}s")
    for i in range(args.batch):
        print(f"  prompt {list(map(int, prompt[i]))} -> "
              f"{list(map(int, out[i]))}")


if __name__ == "__main__":
    main()
