"""Paper Fig. 6: sweep the (N, b) split of a fixed 100-machine budget and
estimate time-to-convergence = iterations(N) x mean iteration time.

    PYTHONPATH=src python examples/backup_sweep.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import events, straggler
from repro.core.aggregation import BackupWorkers


def main(total: int = 100) -> None:
    lat = straggler.PaperCalibrated()
    # iterations(N): interpolate the paper's own Fig. 5 endpoints
    c = (137.5e3 - 76.2e3) / (1 / 50 - 1 / 100)
    a = 76.2e3 - c / 100
    print(f"{'N':>4} {'b':>4} | {'step time':>10} | {'iters':>9} | "
          f"{'est days':>9}")
    print("-" * 50)
    best = (None, np.inf)
    for n in range(50, 101, 2):
        st = events.mean_iteration_time(BackupWorkers(n, total - n), lat,
                                        iters=600, seed=0)
        iters = a + c / n
        t = st * iters
        if t < best[1]:
            best = (n, t)
        bar = "#" * int(40 * min(t / (3 * best[1] if best[0] else t), 1.0))
        print(f"{n:4d} {total - n:4d} | {st:9.2f}s | {iters:9.0f} | "
              f"{t / 86400:9.2f} {bar}")
    n, t = best
    print(f"\noptimum: N={n}, b={total - n} "
          f"(paper found N=96, b=4 — interior optimum either way)")


if __name__ == "__main__":
    main()
