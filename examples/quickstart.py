"""Quickstart: synchronous training with backup workers in ~40 lines.

Trains a tiny LM on the synthetic token stream with N=6 workers + b=2
backups under the paper-calibrated straggler model, and contrasts the
simulated wall time against plain Sync-Opt (b=0).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import PaperCalibrated
from repro.train.loop import Trainer


def make_trainer(tmp, strategy: str, backups: int) -> Trainer:
    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("quickstart", seq_len=32, global_batch=32,
                          kind="train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=6,
                                      backup_workers=backups),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.1,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(directory=tmp, every_steps=25),
        log_every=10,
    )
    tr = Trainer(cfg, latency=PaperCalibrated())
    tr.init_state()
    return tr


def main(steps: int = 60) -> None:
    with tempfile.TemporaryDirectory() as tmp:
        print("== Sync-Opt with backup workers (N=6, b=2) ==")
        tr = make_trainer(tmp + "/b", "backup", 2)
        res = tr.run(steps)
        for m in res.metrics:
            print(f"  step {m['step']:4d} loss {m['loss']:.3f} "
                  f"sim_time {m['sim_time']:7.1f}s selected {m['selected']}")
        backup_time = res.sim_time

    with tempfile.TemporaryDirectory() as tmp:
        print("== plain Sync-Opt (N=8, b=0) — same machine count ==")
        tr = make_trainer(tmp + "/f", "full_sync", 0)
        tr.cfg = tr.cfg  # (full_sync ignores backups)
        res = tr.run(steps)
        print(f"  final loss {res.metrics[-1]['loss']:.3f} "
              f"sim_time {res.sim_time:7.1f}s")
        print(f"\nbackup workers cut simulated time per {steps} steps: "
              f"{res.sim_time:.0f}s -> {backup_time:.0f}s "
              f"({res.sim_time / max(backup_time, 1e-9):.2f}x)")


if __name__ == "__main__":
    main()
