"""Checkpoint/restore, atomicity, keep-k, elastic resume, data-state resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import Uniform
from repro.train import checkpoint as ckpt
from repro.train.loop import Trainer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, {"note": "x"})
    template = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), t)
    restored, manifest = ckpt.restore(str(tmp_path), template)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep(tmp_path):
    t = _tree()
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, t, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((3, 3))})


def test_missing_key_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones(2)})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones(2), "b": jnp.ones(2)})


def _trainer(tmp_path, workers=4, backups=1, steps_ck=5):
    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("tiny", 16, 20, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=workers,
                                      backup_workers=backups),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(directory=str(tmp_path),
                                    every_steps=steps_ck),
        log_every=1)
    return Trainer(cfg, latency=Uniform(1.0, 2.0))


def test_trainer_checkpoint_resume_exact(tmp_path):
    """Kill/restart: a restored trainer continues bit-identically."""
    tr = _trainer(tmp_path)
    tr.init_state()
    tr.run(10)
    tr.save_checkpoint()
    ref_res = tr.run(5)
    ref_loss = [m["loss"] for m in ref_res.metrics[-5:]]

    tr2 = _trainer(tmp_path)
    tr2.restore_checkpoint(step=10)   # the cadence also saved step 15
    assert tr2.step == 10
    res2 = tr2.run(5)
    loss2 = [m["loss"] for m in res2.metrics[-5:]]
    np.testing.assert_allclose(ref_loss, loss2, rtol=1e-5)


def test_elastic_rescale_on_failures(tmp_path):
    """Backups absorb one death; further deaths trigger elastic rescale
    with the lr rule re-applied, and training continues finitely."""
    tr = _trainer(tmp_path, workers=4, backups=1)
    tr.init_state()
    tr.run(3)
    tr.sim.kill_worker(0)           # 4 alive >= N=4: absorbed
    res = tr.run(3)
    assert res.restarts == 0
    tr.sim.kill_worker(1)           # 3 alive < 4 -> rescale
    res = tr.run(4)
    assert res.restarts == 1
    assert tr.cfg.aggregation.total_workers <= 3
    assert all(np.isfinite(m["loss"]) for m in res.metrics)


def test_data_pipeline_state_resumes(tmp_path):
    from repro.data.synthetic_lm import SyntheticLMConfig, SyntheticLMPipeline, PipelineState
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=8, global_batch=4,
                            num_workers=2)
    p1 = SyntheticLMPipeline(cfg)
    for _ in range(3):
        p1.next()
    saved = p1.state.save()
    expect = p1.next()
    p2 = SyntheticLMPipeline(cfg, PipelineState.restore(saved))
    got = p2.next()
    np.testing.assert_array_equal(expect["tokens"], got["tokens"])
