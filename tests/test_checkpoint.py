"""Checkpoint/restore, atomicity, keep-k, elastic resume, data-state resume,
and the self-healing layer: checksums, write retries, crash-mid-save
survival, and walk-back restore past corrupt checkpoints."""
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import Uniform
from repro.train import checkpoint as ckpt
from repro.train.loop import Trainer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, {"note": "x"})
    template = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), t)
    restored, manifest = ckpt.restore(str(tmp_path), template)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep(tmp_path):
    t = _tree()
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, t, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((3, 3))})


def test_missing_key_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones(2)})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones(2), "b": jnp.ones(2)})


def _trainer(tmp_path, workers=4, backups=1, steps_ck=5):
    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("tiny", 16, 20, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=workers,
                                      backup_workers=backups),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.999),
        checkpoint=CheckpointConfig(directory=str(tmp_path),
                                    every_steps=steps_ck),
        log_every=1)
    return Trainer(cfg, latency=Uniform(1.0, 2.0))


def test_trainer_checkpoint_resume_exact(tmp_path):
    """Kill/restart: a restored trainer continues bit-identically."""
    tr = _trainer(tmp_path)
    tr.init_state()
    tr.run(10)
    tr.save_checkpoint()
    ref_res = tr.run(5)
    ref_loss = [m["loss"] for m in ref_res.metrics[-5:]]

    tr2 = _trainer(tmp_path)
    tr2.restore_checkpoint(step=10)   # the cadence also saved step 15
    assert tr2.step == 10
    res2 = tr2.run(5)
    loss2 = [m["loss"] for m in res2.metrics[-5:]]
    np.testing.assert_allclose(ref_loss, loss2, rtol=1e-5)


def test_elastic_rescale_on_failures(tmp_path):
    """Backups absorb one death; further deaths trigger elastic rescale
    with the lr rule re-applied, and training continues finitely."""
    tr = _trainer(tmp_path, workers=4, backups=1)
    tr.init_state()
    tr.run(3)
    tr.sim.kill_worker(0)           # 4 alive >= N=4: absorbed
    res = tr.run(3)
    assert res.restarts == 0
    tr.sim.kill_worker(1)           # 3 alive < 4 -> rescale
    res = tr.run(4)
    assert res.restarts == 1
    assert tr.cfg.aggregation.total_workers <= 3
    assert all(np.isfinite(m["loss"]) for m in res.metrics)


# ---------------------------------------------------------------------------
# Self-healing layer (docs/robustness.md)
# ---------------------------------------------------------------------------


def test_latest_dangling_pointer_falls_back(tmp_path):
    """A LATEST pointing at a deleted dir must not strand the good
    checkpoints still on disk."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    ckpt.save(str(tmp_path), 7, t)
    import shutil
    shutil.rmtree(tmp_path / "step_00000007")   # LATEST now dangles
    assert ckpt.latest_step(str(tmp_path)) == 3
    template = jax.tree_util.tree_map(jnp.zeros_like, t)
    _, manifest = ckpt.restore(str(tmp_path), template)
    assert manifest["step"] == 3


def test_latest_missing_falls_back_to_scan(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    os.remove(tmp_path / "LATEST")
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_restore_walks_back_past_corruption(tmp_path):
    """A truncated arrays.npz in the newest checkpoint falls back to the
    last verified-good one instead of failing the restore."""
    ckpt.save(str(tmp_path), 1, _tree(seed=1))
    ckpt.save(str(tmp_path), 2, _tree(seed=2))
    with open(tmp_path / "step_00000002" / "arrays.npz", "wb") as f:
        f.write(b"not a zip file")
    assert ckpt.find_good_step(str(tmp_path)) == 1
    template = jax.tree_util.tree_map(jnp.zeros_like, _tree())
    restored, manifest = ckpt.restore(str(tmp_path), template)
    assert manifest["step"] == 1
    ref = jax.tree_util.tree_leaves(_tree(seed=1))
    for a, b in zip(ref, jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_detects_silent_bitflip(tmp_path):
    """A bit-flip that keeps the npz readable is caught by the per-array
    CRC32, not silently loaded."""
    t = {"a": jnp.ones((4,), jnp.float32)}
    ckpt.save(str(tmp_path), 1, t)
    path = tmp_path / "step_00000001" / "arrays.npz"
    flat = dict(np.load(path))
    flat["a"][0] = 123.0                       # corrupt, same shape/dtype
    np.savez(path, **flat)
    assert not ckpt.verify(str(tmp_path), 1)
    with pytest.raises(ckpt.CheckpointCorruption):
        ckpt.restore(str(tmp_path), {"a": jnp.zeros(4)}, 1, fallback=False)


def test_save_retries_transient_write_failures(tmp_path):
    """io_check failures below the retry budget back off and succeed;
    each retry is observable via on_retry."""
    fails = {"n": 2}

    def io_check():
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient")

    seen = []
    ckpt.save(str(tmp_path), 1, _tree(), retries=3,
              io_check=io_check, on_retry=lambda a, e: seen.append(a),
              sleep=lambda s: None)
    assert seen == [0, 1]
    assert ckpt.verify(str(tmp_path), 1)
    # no abandoned tmp dirs
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_save_raises_after_retry_budget(tmp_path):
    def io_check():
        raise OSError("disk on fire")

    with pytest.raises(OSError):
        ckpt.save(str(tmp_path), 1, _tree(), retries=2,
                  io_check=io_check, sleep=lambda s: None)
    assert ckpt.latest_step(str(tmp_path)) is None


def test_retry_delays_jittered_capped_seeded():
    d = ckpt.retry_delays(6, 0.01, max_backoff_s=0.05, jitter=0.5, seed=3)
    assert len(d) == 6
    base = [min(0.01 * 2 ** a, 0.05) for a in range(6)]
    for got, b in zip(d, base):
        assert b <= got <= b * 1.5          # within [base, base*(1+jitter)]
    assert d[-1] <= 0.05 * 1.5              # the cap holds at the tail
    assert len(set(round(x / b, 6) for x, b in zip(d, base))) > 1, \
        "jitter must decorrelate the schedule"
    assert d == ckpt.retry_delays(6, 0.01, max_backoff_s=0.05, jitter=0.5,
                                  seed=3), "same seed, same schedule"
    assert d != ckpt.retry_delays(6, 0.01, max_backoff_s=0.05, jitter=0.5,
                                  seed=4)
    assert ckpt.retry_delays(3, 0.01, jitter=0.0) == [0.01, 0.02, 0.04]


def test_save_sleeps_the_jittered_schedule(tmp_path):
    """save's actual sleeps match retry_delays for the same knobs — the
    backoff is observable, capped, and replayable."""
    fails = {"n": 3}

    def io_check():
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient")

    slept = []
    ckpt.save(str(tmp_path), 1, _tree(), retries=4, backoff_s=0.01,
              max_backoff_s=0.02, jitter=0.5, backoff_seed=9,
              io_check=io_check, sleep=slept.append)
    assert slept == ckpt.retry_delays(4, 0.01, max_backoff_s=0.02,
                                      jitter=0.5, seed=9)[:3]
    assert max(slept) <= 0.02 * 1.5
    assert ckpt.verify(str(tmp_path), 1)


def test_crash_mid_save_leaves_previous_checkpoint_good(tmp_path):
    """SIGKILL during a checkpoint write (a real process death, not an
    exception) must leave the previous checkpoint restorable."""
    code = f"""
import os, signal
import jax.numpy as jnp
from repro.train import checkpoint as ckpt
d = {str(tmp_path)!r}
tree = {{"a": jnp.arange(8, dtype=jnp.float32)}}
ckpt.save(d, 1, tree)

def die():
    os.kill(os.getpid(), signal.SIGKILL)   # mid-save, tmp dir exists

ckpt.save(d, 2, tree, io_check=die)
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == -signal.SIGKILL
    # the tmp dir from the killed write is on disk; step 1 is intact
    assert [d for d in os.listdir(tmp_path) if d.startswith(".tmp_ckpt_")]
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, manifest = ckpt.restore(str(tmp_path),
                                      {"a": jnp.zeros(8)})
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(8.0))
    # the next successful save sweeps the abandoned tmp dir
    ckpt.save(str(tmp_path), 3, {"a": jnp.arange(8, dtype=jnp.float32)})
    assert not [d for d in os.listdir(tmp_path)
                if d.startswith(".tmp_ckpt_")]


def test_data_pipeline_state_resumes(tmp_path):
    from repro.data.synthetic_lm import SyntheticLMConfig, SyntheticLMPipeline, PipelineState
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=8, global_batch=4,
                            num_workers=2)
    p1 = SyntheticLMPipeline(cfg)
    for _ in range(3):
        p1.next()
    saved = p1.state.save()
    expect = p1.next()
    p2 = SyntheticLMPipeline(cfg, PipelineState.restore(saved))
    got = p2.next()
    np.testing.assert_array_equal(expect["tokens"], got["tokens"])
