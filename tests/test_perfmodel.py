"""Analytic FLOP/byte model: internal invariants + HLO cross-validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro import configs
from repro.analysis import perfmodel
from repro.configs.base import ShapeConfig, SHAPES_BY_NAME, replace


@given(seq=st.integers(2, 4096), window=st.integers(0, 4096))
@settings(max_examples=50, deadline=None)
def test_avg_kv_bounds(seq, window):
    v = perfmodel._avg_kv(seq, window)
    assert 1.0 <= v <= (seq + 1) / 2 + 1e-9
    if 0 < window < seq:
        assert v <= window
    # exact check against brute force
    w = window if window > 0 else seq
    brute = np.mean([min(i + 1, w) for i in range(seq)])
    assert v == pytest.approx(brute, rel=1e-9)


def test_flops_scaling_relations():
    shape = SHAPES_BY_NAME["train_4k"]
    cfg = configs.get_config("qwen3-0.6b")
    f1 = perfmodel.cell_flops(cfg, shape)
    f2 = perfmodel.cell_flops(replace(cfg, num_layers=2 * cfg.num_layers), shape)
    assert f2.fwd_layers == pytest.approx(2 * f1.fwd_layers, rel=1e-6)
    # remat adds exactly one forward of the layer stack
    f_none = perfmodel.cell_flops(cfg, shape, remat="none")
    assert f1.train - f_none.train == pytest.approx(f1.fwd_layers, rel=1e-6)


def test_moe_flops_use_active_params():
    shape = SHAPES_BY_NAME["train_4k"]
    cfg = configs.get_config("qwen2-moe-a2.7b")
    f = perfmodel.cell_flops(cfg, shape)
    # layer-stack fwd flops must be near 2 * N_active_nonembed * D, far
    # below total-params flops (14.3B)
    from repro.models import registry
    t = shape.global_batch * shape.seq_len
    upper = 2.5 * registry.param_count(cfg, active_only=True) * t
    lower = 2 * 0.4 * registry.param_count(cfg, active_only=True) * t
    assert lower < f.fwd_layers < upper


def test_sliding_window_reduces_attention_flops():
    shape = SHAPES_BY_NAME["prefill_32k"]
    cfg = configs.get_config("gemma3-1b")
    f_win = perfmodel.cell_flops(cfg, shape)
    f_full = perfmodel.cell_flops(replace(cfg, sliding_window=0), shape)
    assert f_win.fwd < f_full.fwd


def test_decode_flops_scale_with_cache():
    cfg = configs.get_config("qwen3-0.6b")
    f32k = perfmodel.cell_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    small = perfmodel.cell_flops(cfg, ShapeConfig("d", 1024, 128, "decode"))
    assert f32k.decode > small.decode


def test_bytes_model_sanity():
    shape = SHAPES_BY_NAME["train_4k"]
    cfg = configs.get_config("qwen3-0.6b")
    b = perfmodel.cell_bytes(cfg, shape, chips=256, model_shard=16)
    b_nozero = perfmodel.cell_bytes(cfg, shape, chips=256, model_shard=16,
                                    zero1=False)
    assert b.train < b_nozero.train            # ZeRO-1 cuts opt traffic
    assert b.fwd < b.train
    d32 = perfmodel.cell_bytes(cfg, SHAPES_BY_NAME["decode_32k"], chips=256,
                               model_shard=16)
    assert d32.cache_bytes > 0
    assert d32.decode > d32.cache_bytes        # params + cache


def test_cache_bytes_family_structure():
    d = SHAPES_BY_NAME["long_500k"]
    rwkv = perfmodel.cell_bytes(configs.get_config("rwkv6-1.6b"), d,
                                chips=256, model_shard=16)
    gemma = perfmodel.cell_bytes(configs.get_config("gemma3-1b"), d,
                                 chips=256, model_shard=16)
    # recurrent state is O(1) in S; gemma's global layers hold real KV
    assert rwkv.cache_bytes < gemma.cache_bytes / 10
    # MLA latent cache beats equivalent GQA cache
    ds = perfmodel.cell_bytes(configs.get_config("deepseek-v2-lite-16b"),
                              SHAPES_BY_NAME["decode_32k"], chips=256,
                              model_shard=16)
    qw = perfmodel.cell_bytes(configs.get_config("qwen2-moe-a2.7b"),
                              SHAPES_BY_NAME["decode_32k"], chips=256,
                              model_shard=16)
    assert ds.cache_bytes < qw.cache_bytes


def test_analytic_flops_vs_hlo_small_model():
    """Cross-validate against XLA's counter on a 2-layer smoke config,
    accounting for the known scan-body-once undercount: expected_hlo =
    3*(fwd_layers/L) + 3*fwd_other (remat none, fwd+bwd counted as 3x)."""
    cfg = replace(configs.get_smoke_config("qwen3-0.6b"), remat="none",
                  tie_embeddings=False, qk_norm=False)
    shape = ShapeConfig("t", 128, 4, "train")
    from repro.models import get_model
    model = get_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 128), jnp.int32)}

    def loss(p, b):
        lt, aux = model.per_token_loss(p, b)
        return lt.mean() + aux

    from repro.launch.dryrun import cost_analysis
    hlo = cost_analysis(
        jax.jit(jax.grad(loss)).lower(params, batch).compile())["flops"]
    f = perfmodel.cell_flops(cfg, shape, remat="none")
    expected = 3 * (f.fwd_layers / cfg.num_layers) + 3 * f.fwd_other
    # matmul-dominated: within 35% (HLO counts softmax/norm vector ops too)
    assert expected == pytest.approx(hlo, rel=0.35)
