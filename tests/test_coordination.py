"""The unified coordination API: Trainer-vs-legacy bit-exactness and the
deprecation-shim contract (warn once, signatures frozen)."""
import inspect
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import tiny_lm_config
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig,
                                replace)
from repro.core import async_sim, coordination
from repro.core.straggler import Uniform
from repro.data.synthetic_lm import SyntheticLMConfig, worker_batch
from repro.models import get_model
from repro.optim import make_optimizer, schedules
from repro.train.loop import run_experiment


def _event_cfg(tmp_path, strategy, workers=4, updates=30, **agg_kw):
    return TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 16, 4 * workers, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      **agg_kw),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.3,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0),
        seed=3, total_steps=updates, log_every=1)


def _legacy_ingredients(cfg):
    """The exact model/grad/update/batch functions the Trainer builds."""
    model = get_model(cfg.model)
    params0 = model.init(jax.random.PRNGKey(cfg.seed))
    grad_fn = coordination.make_grad_fn(model)
    sched = schedules.from_config(cfg.optimizer, cfg.aggregation.num_workers)
    opt = make_optimizer(cfg.optimizer, sched)
    upd = coordination.make_update_fn(opt, cfg.optimizer.clip_global_norm)

    def update_fn(params, opt_state, grads, step):
        if opt_state is None:
            opt_state = opt.init(params)
        p, o, _ = upd(params, opt_state, grads, jnp.asarray(step, jnp.int32))
        return p, o

    data_cfg = SyntheticLMConfig(
        vocab_size=cfg.model.vocab_size, seq_len=cfg.shape.seq_len,
        global_batch=cfg.shape.global_batch,
        num_workers=cfg.aggregation.num_workers, seed=cfg.seed)

    def batch_fn(worker, draw):
        b = worker_batch(data_cfg, worker, draw)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return params0, grad_fn, update_fn, batch_fn


def _first_leaf(tree):
    return np.asarray(jax.tree_util.tree_leaves(tree)[0])


def test_trainer_async_bit_exact_vs_legacy_simulator(tmp_path):
    """Acceptance: the Trainer-driven async path replays the legacy
    ``simulate_async`` update/staleness sequence EXACTLY — same seed,
    same latency model, bit-identical params and EMA."""
    cfg = _event_cfg(tmp_path, "async", workers=4, updates=30)
    lat = Uniform(1.0, 2.0)
    res = run_experiment(cfg, latency=lat)

    params0, grad_fn, update_fn, batch_fn = _legacy_ingredients(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        leg = async_sim.simulate_async(
            grad_fn, update_fn, params0, batch_fn, num_workers=4,
            num_updates=30, latency=lat, seed=cfg.seed, ema_decay=0.99)

    # identical staleness sequence and update (sim) times, update for update
    np.testing.assert_array_equal(
        np.array([m["staleness"] for m in res.metrics]),
        leg.staleness.astype(float))
    np.testing.assert_array_equal(
        np.array([m["sim_time"] for m in res.metrics]), leg.sim_time)
    # bit-identical final params and EMA
    np.testing.assert_array_equal(_first_leaf(res.params),
                                  _first_leaf(leg.params))
    np.testing.assert_array_equal(_first_leaf(res.ema), _first_leaf(leg.ema))
    assert res.steps == leg.updates
    assert res.mean_staleness == pytest.approx(leg.staleness.mean())


def test_trainer_softsync_bit_exact_vs_legacy_simulator(tmp_path):
    cfg = _event_cfg(tmp_path, "softsync", workers=4, updates=15,
                     softsync_c=2)
    cfg = replace(cfg, optimizer=replace(cfg.optimizer, ema_decay=0.0))
    lat = Uniform(1.0, 2.0)
    res = run_experiment(cfg, latency=lat)

    params0, grad_fn, update_fn, batch_fn = _legacy_ingredients(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        leg = async_sim.simulate_softsync(
            grad_fn, update_fn, params0, batch_fn, num_workers=4, c=2,
            num_updates=15, latency=lat, seed=cfg.seed)

    np.testing.assert_array_equal(
        np.array([m["sim_time"] for m in res.metrics]), leg.sim_time)
    np.testing.assert_array_equal(_first_leaf(res.params),
                                  _first_leaf(leg.params))
    # softsync aggregates exactly c gradients per update
    assert all(m["selected"] == 2 for m in res.metrics)
    assert res.mean_staleness == pytest.approx(leg.staleness.mean())


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------


def _quadratic():
    rng = np.random.RandomState(0)
    x = rng.randn(256, 4).astype(np.float32)
    y = (x @ rng.randn(4).astype(np.float32))

    def batch_fn(worker, draw):
        r = np.random.RandomState(worker * 1000 + draw)
        idx = r.randint(0, 256, size=16)
        return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}

    @jax.jit
    def grad_fn(params, batch):
        def loss(p):
            return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)
        return jax.value_and_grad(loss)(params)

    def update_fn(params, opt_state, grads, step):
        return (jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params,
                                       grads), opt_state)

    return grad_fn, update_fn, {"w": jnp.zeros(4)}, batch_fn


@pytest.mark.parametrize("entry", ["simulate_async", "simulate_softsync",
                                   "simulate_staleness", "from_config"])
def test_deprecation_warns_exactly_once(entry):
    coordination._WARNED.clear()
    grad_fn, update_fn, params0, batch_fn = _quadratic()

    def call():
        if entry == "simulate_async":
            async_sim.simulate_async(grad_fn, update_fn, params0, batch_fn,
                                     num_workers=2, num_updates=3,
                                     latency=Uniform(1.0, 1.5))
        elif entry == "simulate_softsync":
            async_sim.simulate_softsync(grad_fn, update_fn, params0, batch_fn,
                                        num_workers=2, c=2, num_updates=3,
                                        latency=Uniform(1.0, 1.5))
        elif entry == "simulate_staleness":
            async_sim.simulate_staleness(grad_fn, update_fn, params0,
                                         lambda s: batch_fn(0, s),
                                         num_updates=3, staleness=1)
        else:
            from repro.core import aggregation
            aggregation.from_config(AggregationConfig(strategy="backup",
                                                      num_workers=2,
                                                      backup_workers=1))

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        call()
        call()
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, (entry, [str(w.message) for w in dep])


def test_legacy_signatures_unchanged():
    """The shims keep the exact legacy parameter lists and defaults, so
    every pre-registry call site (tests/test_async_sim.py included)
    keeps working unmodified."""
    sig = inspect.signature(async_sim.simulate_async)
    assert list(sig.parameters) == ["grad_fn", "update_fn", "params0",
                                    "batch_fn", "num_workers", "num_updates",
                                    "latency", "seed", "ema_decay"]
    assert sig.parameters["ema_decay"].default == 0.0
    sig = inspect.signature(async_sim.simulate_softsync)
    assert list(sig.parameters) == ["grad_fn", "update_fn", "params0",
                                    "batch_fn", "num_workers", "c",
                                    "num_updates", "latency", "seed"]
    sig = inspect.signature(async_sim.simulate_staleness)
    assert list(sig.parameters) == ["grad_fn", "update_fn", "params0",
                                    "batch_fn", "num_updates", "staleness",
                                    "ramp_steps", "ema_decay", "jitter",
                                    "seed"]
    assert sig.parameters["ramp_steps"].default == 0
    assert sig.parameters["jitter"].default == 0
