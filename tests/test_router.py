"""Replica router core: StepSession incremental parity, single-replica
token equivalence with the engine, deterministic replay, hedged backup
requests, timeout/retry with jittered backoff, SLO admission (shed and
queue modes, checkpointable controller state), and graceful rejection
paths. Chaos/failover scenarios live in test_router_chaos.py."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import get_model
from repro.serve import (ReplicaRouter, Request, RouterConfig, SLOConfig,
                         SLOController, ServeEngine, StepSession,
                         TraceConfig, make_trace)


def _trace(n=12, *, seed=0, rate=2.0, max_prompt=12, max_new=8, vocab=128,
           min_new=2):
    return make_trace(TraceConfig(
        num_requests=n, rate=rate, prompt_len_min=2, prompt_len_max=max_prompt,
        max_new_min=min_new, max_new_max=max_new, vocab=vocab, seed=seed))


@pytest.fixture(scope="module")
def qwen():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(qwen):
    cfg, _, params = qwen
    return ServeEngine(cfg, params, num_slots=2, page_size=4,
                       max_prompt_len=12, max_new_cap=8, clock="virtual")


def _accounted(report, trace):
    done = {c.rid for c in report.completed}
    rej = {r["rid"] for r in report.rejected}
    assert not done & rej
    assert done | rej == {r.rid for r in trace}
    assert report.metrics["lost_requests"] == 0


# ---------------------------------------------------------------------------
# StepSession: the incremental per-replica surface
# ---------------------------------------------------------------------------


def test_step_session_matches_engine_tokens(engine):
    trace = _trace(4, rate=1000.0)        # all arrive ~immediately
    ref = engine.run(trace).tokens_by_rid()
    sess = StepSession(engine)
    got = {}
    backlog = list(trace)
    while backlog or sess.active:
        while backlog and sess.can_admit(backlog[0]):
            req = backlog.pop(0)
            st = sess.admit(req, 0.0, 0.0)
            if sess.done(st):
                got[req.rid] = sess.release(req.rid).tokens
        for rid in sess.tick():
            got[rid] = sess.release(rid).tokens
    assert got == ref


def test_step_session_release_frees_everything(engine):
    sess = StepSession(engine)
    free0 = sess.pool.free_pages
    req = _trace(1, rate=1000.0)[0]
    sess.admit(req, 0.0, 0.0)
    assert sess.pool.free_pages < free0
    sess.release(req.rid)
    assert sess.pool.free_pages == free0
    assert not sess.active and len(sess.free_slots) == 2


def test_step_session_evict_all_orders_by_slot(engine):
    sess = StepSession(engine)
    trace = _trace(2, rate=1000.0, min_new=4, max_new=8)
    for r in trace:
        sess.admit(r, 0.0, 0.0)
    sts = sess.evict_all()
    assert [st.req.rid for st in sts] == sorted(st.req.rid for st in sts)
    assert sess.pool.free_pages == engine.pool_cfg.num_pages - 1


# ---------------------------------------------------------------------------
# Router: equivalence, replay, spread
# ---------------------------------------------------------------------------


def test_single_replica_token_parity(engine):
    trace = _trace(8)
    ref = engine.run(trace).tokens_by_rid()
    rep = ReplicaRouter(engine, RouterConfig(num_replicas=1)).run(trace)
    _accounted(rep, trace)
    assert rep.tokens_by_rid() == ref


def test_multi_replica_token_parity_and_spread(engine):
    trace = _trace(12)
    ref = engine.run(trace).tokens_by_rid()
    rep = ReplicaRouter(engine, RouterConfig(num_replicas=3)).run(trace)
    _accounted(rep, trace)
    assert rep.tokens_by_rid() == ref
    assert len({c.replica for c in rep.completed}) > 1, \
        "least-loaded dispatch should spread across replicas"


def test_replay_bit_identical(engine):
    trace = _trace(12)
    mk = lambda: ReplicaRouter(  # noqa: E731
        engine, RouterConfig(num_replicas=3, hedge_after=6.0,
                             timeout=50.0)).run(trace)
    a, b = mk(), mk()
    assert a.metrics == b.metrics
    assert a.events == b.events
    assert a.health == b.health
    assert a.tokens_by_rid() == b.tokens_by_rid()
    assert [dataclasses.astuple(c) for c in a.completed] == \
        [dataclasses.astuple(c) for c in b.completed]


# ---------------------------------------------------------------------------
# Hedged backup requests
# ---------------------------------------------------------------------------


def test_hedging_routes_around_straggler(engine):
    trace = _trace(24, min_new=4)
    spec = "slowdown@0:r0:x10:d400"
    unhedged = ReplicaRouter(engine, RouterConfig(
        num_replicas=3, faults=spec)).run(trace)
    hedged = ReplicaRouter(engine, RouterConfig(
        num_replicas=3, faults=spec, hedge_after=6.0)).run(trace)
    _accounted(hedged, trace)
    assert hedged.metrics["hedges"] > 0
    assert hedged.metrics["hedge_wins"] > 0
    assert hedged.metrics["p99_latency"] < unhedged.metrics["p99_latency"]
    # greedy decode: a hedge changes who answers, never the answer
    assert hedged.tokens_by_rid() == engine.run(trace).tokens_by_rid()
    assert any(c.hedged for c in hedged.completed)


def test_hedge_win_release_during_tick_sweep(engine):
    # regression: a hedge win releasing the loser replica mid-sweep used
    # to pop its next_tick entry out from under the decode-tick loop
    # (KeyError); the loser here is the slow replica with no other work
    trace = _trace(24, rate=2.0, min_new=4)
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=2, faults="slowdown@0:r1:x20:d200",
        hedge_after=3.0)).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["hedges"] > 0


def test_hedge_threshold_tracks_window():
    r = ReplicaRouter.__new__(ReplicaRouter)
    r.cfg = RouterConfig(num_replicas=2, hedge_after=5.0,
                         hedge_min_samples=4, hedge_quantile=95.0)
    assert r._hedge_threshold([]) == 5.0          # cold: floor applies
    assert r._hedge_threshold([1.0, 1.0]) == 5.0  # still warming
    assert r._hedge_threshold([1.0] * 8) == 5.0   # floor beats tiny p95
    big = r._hedge_threshold([20.0] * 8)
    assert big == pytest.approx(20.0)             # window beats the floor


# ---------------------------------------------------------------------------
# Prefill-only completion causality
# ---------------------------------------------------------------------------


def test_prefill_only_completion_lands_at_ft(engine):
    # max_new=1 requests finish at prefill; completion is an event at
    # admitted + prefill_time on the virtual clock, never recorded early
    trace = _trace(4, rate=1000.0, min_new=1, max_new=1)
    rep = ReplicaRouter(engine, RouterConfig(num_replicas=2)).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == len(trace)
    for c in rep.completed:
        assert c.finish == pytest.approx(c.admitted + 1.0)
        assert c.finish == c.first_token


def test_prefill_completion_cancelled_by_crash(engine):
    # the replica dies between admission and prefill-finish: the request
    # must drain and recompute elsewhere, not count as completed before
    # the clock ever reached its finish time
    trace = _trace(1, rate=1000.0, min_new=1, max_new=1)
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=2, faults="crash@1:r0")).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == 1
    (c,) = rep.completed
    assert c.drains == 1
    assert c.finish == pytest.approx(2.0)   # re-prefilled on the survivor


# ---------------------------------------------------------------------------
# Timeout + jittered retry
# ---------------------------------------------------------------------------


def test_timeout_retries_then_succeeds(engine):
    # one replica, slowed 50x for 20 steps: first attempts time out, the
    # backoff lands after the slowdown window and the retries complete
    trace = _trace(4, rate=2.0, min_new=2, max_new=4)
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=1, timeout=8.0, max_retries=3, backoff=8.0,
        faults="slowdown@0:r0:x50:d20")).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["timeouts"] > 0
    assert rep.metrics["retries"] > 0
    assert rep.metrics["completed"] == len(trace)
    assert any(c.retries > 0 for c in rep.completed)


def test_timeout_budget_exhaustion_rejects_structured(engine):
    trace = _trace(6, min_new=4)
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=2, timeout=5.0, max_retries=1,
        faults="slowdown@0:r0:x50:d400,slowdown@0:r1:x50:d400")).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == 0
    assert all(r["reason"] == "timeout" for r in rep.rejected)


def test_retry_backoff_is_jittered_and_capped(engine):
    trace = _trace(6, min_new=4)
    cfg = RouterConfig(num_replicas=1, timeout=5.0, max_retries=3,
                       backoff=1.0, max_backoff=2.0, jitter=0.5,
                       faults="slowdown@0:r0:x50:d400")
    rep = ReplicaRouter(engine, cfg).run(trace)
    delays = [e["delay"] for e in rep.events if e["event"] == "retry"]
    assert delays, "slow replica must trigger retries"
    for d in delays:
        assert 1.0 <= d <= 2.0 * 1.5       # within cap * (1 + jitter)
    assert len(set(delays)) > 1, "jitter must decorrelate retry delays"
    rep2 = ReplicaRouter(engine, cfg).run(trace)
    assert delays == [e["delay"] for e in rep2.events
                      if e["event"] == "retry"], "jitter is seeded"


# ---------------------------------------------------------------------------
# SLO admission
# ---------------------------------------------------------------------------


def _overload(n=48, seed=3):
    # sustained overload for a 2-slot single replica: queueing delay grows
    # until the windowed p99 trips the controller mid-trace
    return _trace(n, seed=seed, rate=1.0, min_new=4, max_new=8)


def test_slo_shed_caps_latency_under_overload(engine):
    trace = _overload()
    base = ReplicaRouter(engine, RouterConfig(num_replicas=1)).run(trace)
    slo = SLOConfig(target_p99=10.0, window=16, min_samples=4)
    rep = ReplicaRouter(engine, RouterConfig(num_replicas=1),
                        slo=slo).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["shed"] > 0
    assert rep.metrics["slo_trips"] >= 1
    assert all(r["reason"] == "slo_shed" for r in rep.rejected)
    assert rep.metrics["p99_latency"] < base.metrics["p99_latency"] * 0.6, \
        "shedding must cap the served tail, not just drop requests"


def _burst_then_trickle(n_burst=24, n_tail=20, gap=12.0, seed=3):
    # overload burst, then a sparse tail: the controller must trip during
    # the burst and re-open (hysteresis) once probe latencies recover
    burst = _trace(n_burst, seed=seed, rate=4.0, min_new=4)
    tail = _trace(n_tail, seed=seed + 1, rate=0.15, min_new=2, max_new=4)
    t0 = burst[-1].arrival + gap
    return list(burst) + [
        dataclasses.replace(r, rid=n_burst + r.rid, arrival=t0 + r.arrival)
        for r in tail]


def test_slo_sheds_then_reenters_target(engine):
    trace = _burst_then_trickle()
    slo = SLOConfig(target_p99=15.0, window=8, min_samples=4,
                    quantile=90.0, probe_every=2)
    rep = ReplicaRouter(engine, RouterConfig(num_replicas=1),
                        slo=slo).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["shed"] > 0
    assert rep.metrics["slo_trips"] >= 1
    assert rep.metrics["slo_reentered"] == 1, \
        "once the burst drains, probe latencies must re-open the gate"
    # requests served after re-entry are fresh, not backlogged
    tail_done = [c for c in rep.completed if c.rid >= 24]
    assert tail_done and any(c.latency < 15.0 for c in tail_done)


def test_slo_queue_mode_holds_instead_of_dropping(engine):
    trace = _overload()
    slo = SLOConfig(target_p99=15.0, mode="queue", window=16, min_samples=4)
    rep = ReplicaRouter(engine, RouterConfig(num_replicas=1),
                        slo=slo).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == len(trace), \
        "queue mode delays load, it never drops it"
    assert rep.metrics["slo_trips"] >= 1
    assert rep.tokens_by_rid() == engine.run(trace).tokens_by_rid()


def test_slo_controller_state_roundtrip():
    a = SLOController(SLOConfig(target_p99=10.0, window=8, min_samples=4))
    for x in [1.0, 2.0, 30.0, 40.0, 50.0]:
        a.observe(x)
    b = SLOController(SLOConfig(target_p99=10.0, window=8, min_samples=4))
    b.load_state_dict(a.state_dict())
    assert b.estimate() == a.estimate()
    assert b.violating == a.violating
    for x in [1.0, 1.0, 1.0, 2.0]:
        a.observe(x)
        b.observe(x)
        assert a.admit(0.0) == b.admit(0.0)
    assert b.state_dict() == a.state_dict()


def test_slo_config_validation():
    with pytest.raises(ValueError, match="mode"):
        SLOConfig(target_p99=1.0, mode="panic")
    with pytest.raises(ValueError, match="target_p99"):
        SLOConfig(target_p99=0.0)


# ---------------------------------------------------------------------------
# Graceful rejection + config validation
# ---------------------------------------------------------------------------


def test_router_queue_overflow_sheds_structured(engine):
    trace = _trace(16, rate=1000.0)       # a burst lands all at once
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=1, max_queue=3)).run(trace)
    _accounted(rep, trace)
    over = [r for r in rep.rejected if r["reason"] == "queue_overflow"]
    assert over, "burst past the waiting-room bound must shed"
    assert rep.metrics["completed"] >= 3


def test_router_pool_exhausted_reject(qwen):
    cfg, _, params = qwen
    tiny = ServeEngine(cfg, params, num_slots=2, page_size=4,
                       max_prompt_len=12, max_new_cap=8, clock="virtual",
                       num_pages=3, strict_capacity=False)
    trace = _trace(4, max_prompt=12, min_new=4)
    rep = ReplicaRouter(tiny, RouterConfig(num_replicas=2)).run(trace)
    _accounted(rep, trace)
    assert any(r["reason"] == "pool_exhausted" for r in rep.rejected)


def test_router_rejects_training_only_fault_kinds(engine):
    with pytest.raises(ValueError, match="ckpt_io"):
        ReplicaRouter(engine, RouterConfig(num_replicas=2,
                                           faults="ckpt_io@3:r0"))


def test_router_rejects_out_of_range_replica(engine):
    with pytest.raises(ValueError, match="replica 5"):
        ReplicaRouter(engine, RouterConfig(num_replicas=2,
                                           faults="crash@3:r5"))


def test_router_config_validation():
    with pytest.raises(ValueError, match="num_replicas"):
        RouterConfig(num_replicas=0)
    with pytest.raises(ValueError, match="step_time"):
        RouterConfig(num_replicas=2, step_time=0.0)
