"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (the assignment's required smoke contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model
from repro.optim import optimizers as opt_lib
from repro.optim import schedules

ARCHS = configs.list_archs()


def make_batch(cfg, b=2, s=16, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    batch = {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            k1, (b, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.family == "audio":
        batch["encoder_frames"] = 0.1 * jax.random.normal(
            k1, (b, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss, aux = model.per_token_loss(params, batch)
    expect_s = 16 + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    assert loss.shape == (2, expect_s)
    assert not bool(jnp.isnan(loss).any())
    assert float(loss.mean()) > 0

    # one SGD step decreases loss on the same batch (sanity of grads)
    def scalar_loss(p):
        lt, a = model.per_token_loss(p, batch)
        return lt.mean() + a

    l0, g = jax.value_and_grad(scalar_loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert not bool(jnp.isnan(leaf).any())
    params2 = jax.tree_util.tree_map(lambda p, gg: p - 0.2 * gg, params, g)
    l1 = scalar_loss(params2)
    assert float(l1) < float(l0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_deterministic(arch):
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    l1, _ = model.per_token_loss(params, batch)
    l2, _ = model.per_token_loss(params, batch)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_full_configs_match_published_param_counts():
    from repro.models import registry
    expected = {
        "qwen2-moe-a2.7b": (14.3e9, 0.15),
        "deepseek-v2-lite-16b": (15.7e9, 0.15),
        "internvl2-2b": (1.9e9, 0.25),
        "gemma3-1b": (0.9e9, 0.25),
        "qwen3-0.6b": (0.6e9, 0.25),
        "minitron-4b": (4.2e9, 0.15),
        "command-r-plus-104b": (104e9, 0.10),
        "hymba-1.5b": (1.5e9, 0.25),
        "rwkv6-1.6b": (1.6e9, 0.25),
        "whisper-tiny": (39e6, 1.0),     # ours adds learned pos for 64k ctx
    }
    for arch, (target, tol) in expected.items():
        n = registry.param_count(configs.get_config(arch))
        assert abs(n - target) / target <= tol, (arch, n, target)


def test_moe_active_params_below_total():
    from repro.models import registry
    for arch in ("qwen2-moe-a2.7b", "deepseek-v2-lite-16b"):
        cfg = configs.get_config(arch)
        assert registry.param_count(cfg, active_only=True) \
            < 0.35 * registry.param_count(cfg)


def test_mnist_cnn_smoke():
    from repro.models import mnist_cnn
    model = mnist_cnn.make()
    params = model.init(jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 28, 28, 1))
    logits = model.forward(params, imgs)
    assert logits.shape == (4, 10)
    labels = jnp.asarray([0, 1, 2, 3])
    loss = model.per_example_loss(params, {"images": imgs, "labels": labels})
    assert loss.shape == (4,)
    assert not bool(jnp.isnan(loss).any())
