"""Manual-TP spec logic (distributed/sharding.py): pure host-side rules.

Edge cases exposed by the tensor-parallel SPMD engine: group-consistency
(all-or-nothing sharding per parameter group), params not divisible by
mesh_model, scalar/1-D leaves (biases, norm scales), and optimizer-state
pytrees whose structure differs from params.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import replace
from repro.distributed.sharding import (TPPlan, tp_local_model_cfg, tp_param_spec,
                                        tp_param_specs, tp_plan, tp_state_specs)


def _tiny(**kw):
    base = dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, vocab_pad_multiple=16)
    base.update(kw)
    return replace(configs.get_smoke_config("qwen3-0.6b"), **base)


# ---------------------------------------------------------------------------
# tp_plan: divisibility + group consistency
# ---------------------------------------------------------------------------


def test_plan_all_groups_shard_when_divisible():
    plan = tp_plan(_tiny(), 2)
    assert plan == TPPlan(2, attn=True, ffn=True, vocab=True)
    assert plan.any


def test_plan_trivial_for_size_one_or_no_cfg():
    assert not tp_plan(_tiny(), 1).any
    assert not tp_plan(None, 4).any


def test_plan_attn_group_is_all_or_nothing():
    # q heads divide but kv heads do NOT: sharding wq while replicating
    # wk/wv would change q_per_kv on the shard — the whole group opts out
    plan = tp_plan(_tiny(num_heads=4, num_kv_heads=1), 2)
    assert not plan.attn
    assert plan.ffn and plan.vocab          # other groups unaffected
    # odd q heads: out too
    assert not tp_plan(_tiny(num_heads=3, num_kv_heads=3), 2).attn


def test_plan_bias_blocks_row_parallel_groups():
    # a biased wo/w_down would add its bias mesh_model times before the
    # psum — biased configs keep attention and FFN replicated
    plan = tp_plan(_tiny(use_bias=True), 2)
    assert not plan.attn and not plan.ffn
    assert plan.vocab                       # embed/head carry no bias


def test_plan_indivisible_ffn_and_vocab():
    assert not tp_plan(_tiny(d_ff=66), 4).ffn
    assert not tp_plan(_tiny(vocab_size=60, vocab_pad_multiple=4), 16).vocab


def test_plan_non_transformer_families_replicate():
    rwkv = configs.get_smoke_config("rwkv6-1.6b")
    assert not tp_plan(rwkv, 2).any
    whisper = configs.get_smoke_config("whisper-tiny")
    assert not tp_plan(whisper, 2).any


def test_plan_mla_attention_replicates():
    dsv2 = configs.get_smoke_config("deepseek-v2-lite-16b")
    assert dsv2.attention_kind == "mla"
    assert not tp_plan(dsv2, 2).attn


# ---------------------------------------------------------------------------
# tp_param_spec(s): leaf rules on a REAL parameter tree
# ---------------------------------------------------------------------------


def _param_shapes(cfg):
    from repro.models import get_model
    return jax.eval_shape(get_model(cfg).init, jax.random.PRNGKey(0))


def test_param_specs_on_real_tree():
    cfg = _tiny()
    specs = tp_param_specs(tp_plan(cfg, 2), _param_shapes(cfg))
    seg = specs["seg_dense"]
    # stacked [L, ...] leaves: the layer dim is never sharded
    assert seg["attn"]["wq"]["w"] == P(None, None, "model")
    assert seg["attn"]["wo"]["w"] == P(None, "model", None)
    assert seg["mlp"]["w_up"]["w"] == P(None, None, "model")
    assert seg["mlp"]["w_down"]["w"] == P(None, "model", None)
    assert specs["embed"]["embedding"] == P("model", None)
    # 1-D leaves (norm scales) replicated
    assert seg["ln1"]["scale"] == P(None, None)
    assert seg["attn"]["q_norm"]["scale"] == P(None, None)
    assert specs["final_norm"]["scale"] == P(None)


def test_param_spec_divisibility_guard_per_leaf():
    # plan says shard, but THIS leaf's dim doesn't divide -> replicated
    plan = TPPlan(4, attn=True, ffn=False, vocab=False)
    assert tp_param_spec("seg_dense/attn/wq/w", (1, 32, 30), plan) == \
        P(None, None, None)
    # scalars / 0-d never touched
    assert tp_param_spec("whatever/scalar", (), plan) == P()


def test_param_spec_untied_head_sharded():
    cfg = _tiny(tie_embeddings=False)
    specs = tp_param_specs(tp_plan(cfg, 2), _param_shapes(cfg))
    assert specs["lm_head"]["w"] == P(None, "model")


# ---------------------------------------------------------------------------
# tp_state_specs: opt-state trees whose STRUCTURE differs from params
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,keys", [
    ("momentum", ["m"]),
    ("rmsprop_momentum", ["ms", "mom"]),
    ("adam", ["m", "v"]),
])
def test_state_specs_inherit_param_specs(name, keys):
    from repro.optim import optimizers as opt_lib, schedules

    cfg = _tiny()
    plan = tp_plan(cfg, 2)
    params_t = _param_shapes(cfg)
    opt = getattr(opt_lib, name)(schedules.constant(0.1))
    opt_t = jax.eval_shape(opt.init, params_t)
    specs = tp_state_specs(plan, opt_t)
    pspecs = tp_param_specs(plan, params_t)
    for k in keys:
        assert k in specs
        # every state leaf mirrors its parameter's spec, leaf-for-leaf
        assert jax.tree_util.tree_structure(specs[k], is_leaf=lambda x: isinstance(x, P)) == \
            jax.tree_util.tree_structure(pspecs, is_leaf=lambda x: isinstance(x, P))
        assert jax.tree_util.tree_leaves(specs[k], is_leaf=lambda x: isinstance(x, P)) == \
            jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))


def test_state_specs_empty_sgd_state():
    from repro.optim import optimizers as opt_lib, schedules

    cfg = _tiny()
    opt = opt_lib.sgd(schedules.constant(0.1))
    opt_t = jax.eval_shape(opt.init, _param_shapes(cfg))
    assert tp_state_specs(tp_plan(cfg, 2), opt_t) == {}


def test_state_specs_ema_tree():
    from repro.core import ema as ema_lib

    cfg = _tiny()
    plan = tp_plan(cfg, 2)
    params_t = _param_shapes(cfg)
    ema_t = jax.eval_shape(ema_lib.init, params_t)
    specs = tp_state_specs(plan, ema_t)
    assert specs["embed"]["embedding"] == P("model", None)
    assert specs["seg_dense"]["ln1"]["scale"] == P(None, None)


# ---------------------------------------------------------------------------
# tp_local_model_cfg: the per-shard model config
# ---------------------------------------------------------------------------


def test_local_cfg_divides_sharded_groups_and_pins_head_dim():
    cfg = _tiny(head_dim=0)                 # derived head_dim = d_model/heads
    plan = tp_plan(cfg, 2)
    local = tp_local_model_cfg(cfg, plan)
    assert local.num_heads == 1 and local.num_kv_heads == 1
    assert local.d_ff == 32
    # derived head dim would change with num_heads; it must be pinned
    assert local.resolved_head_dim == cfg.resolved_head_dim
    # vocab fields stay GLOBAL (handled by tp.sharded_embed / CE)
    assert local.vocab_size == cfg.vocab_size
    assert local.padded_vocab == cfg.padded_vocab


def test_local_cfg_identity_without_plan():
    cfg = _tiny()
    assert tp_local_model_cfg(cfg, TPPlan(2)) is cfg


def test_local_cfg_respects_partial_plans():
    cfg = _tiny(num_heads=3, num_kv_heads=3)    # attn can't shard
    plan = tp_plan(cfg, 2)
    local = tp_local_model_cfg(cfg, plan)
    assert local.num_heads == 3                 # untouched
    assert local.d_ff == 32                     # ffn still shards
