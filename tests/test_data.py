"""Data pipelines: determinism, worker-shard disjointness, learnability."""
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.data import mnist_like, synthetic_lm


def test_worker_batches_deterministic():
    cfg = synthetic_lm.SyntheticLMConfig(vocab_size=128, seq_len=16,
                                         global_batch=8, num_workers=4)
    a = synthetic_lm.worker_batch(cfg, 1, 5)
    b = synthetic_lm.worker_batch(cfg, 1, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


@given(w1=st.integers(0, 3), w2=st.integers(0, 3), step=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_worker_shards_differ(w1, w2, step):
    cfg = synthetic_lm.SyntheticLMConfig(vocab_size=4096, seq_len=32,
                                         global_batch=8, num_workers=4)
    a = synthetic_lm.worker_batch(cfg, w1, step)
    b = synthetic_lm.worker_batch(cfg, w2, step)
    if w1 == w2:
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    else:
        assert not np.array_equal(a["tokens"], b["tokens"])


def test_global_batch_is_worker_concat():
    cfg = synthetic_lm.SyntheticLMConfig(vocab_size=128, seq_len=8,
                                         global_batch=8, num_workers=4)
    g = synthetic_lm.global_batch(cfg, 3)
    assert g["tokens"].shape == (8, 8)
    w1 = synthetic_lm.worker_batch(cfg, 1, 3)
    np.testing.assert_array_equal(g["tokens"][2:4], w1["tokens"])


def test_labels_are_next_tokens():
    cfg = synthetic_lm.SyntheticLMConfig(vocab_size=128, seq_len=16,
                                         global_batch=4, num_workers=2,
                                         noise=0.0)
    b = synthetic_lm.worker_batch(cfg, 0, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_stream_is_learnable():
    """noise=0.1 Markov stream: the next token is predictable 90% of the
    time from the previous one — a model must be able to beat ln(V)."""
    cfg = synthetic_lm.SyntheticLMConfig(vocab_size=64, seq_len=64,
                                         global_batch=16, num_workers=1,
                                         noise=0.1)
    b = synthetic_lm.worker_batch(cfg, 0, 0)
    a, off = synthetic_lm._transition(64, cfg.seed)
    pred = (a * b["tokens"] + off) % 64
    acc = (pred == b["labels"]).mean()
    assert acc > 0.75


def test_mnist_like_dataset():
    cfg = mnist_like.MnistLikeConfig(num_train=256, num_test=128)
    train, test = mnist_like.make_dataset(cfg)
    assert train["images"].shape == (256, 28, 28, 1)
    assert test["labels"].shape == (128,)
    assert set(np.unique(train["labels"])) <= set(range(10))
    # classes are separable: per-class template means differ
    m0 = train["images"][train["labels"] == 0].mean(0)
    m1 = train["images"][train["labels"] == 1].mean(0)
    assert np.abs(m0 - m1).mean() > 0.1


def test_mnist_batches_deterministic():
    cfg = mnist_like.MnistLikeConfig(num_train=128, num_test=32)
    train, _ = mnist_like.make_dataset(cfg)
    b1 = list(mnist_like.batches(train, 16, seed=3, steps=4))
    b2 = list(mnist_like.batches(train, 16, seed=3, steps=4))
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x["labels"], y["labels"])
