"""Doc-drift guards: the documentation system is tested like code.

Three contracts, enforced at tier-1 so a PR cannot silently break them:

* every coordination strategy in ``core/registry`` is documented in
  docs/api.md (the protocol/migration/metrics home);
* every top-level key of every ``BENCH_*.json`` artifact (repo-root
  mirrors AND the full ``experiments/bench`` payloads) is documented in
  the "Bench JSON schema" section of docs/perf.md — numeric suffixes are
  normalized (``speedup_32_vs_1`` matches the documented
  ``speedup_32_vs_1`` literal or a ``speedup_N_vs_N`` pattern), so
  adding a matrix cell doesn't require a doc edit but adding a new KIND
  of key does;
* every relative markdown link (and ``#anchor``) in the repo's *.md
  files resolves — README, docs/, and the repo root are checked with a
  GitHub-style slugifier.
"""
import json
import os
import re
import string

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# Registry <-> docs/api.md
# ---------------------------------------------------------------------------


def _read(*parts: str) -> str:
    with open(os.path.join(ROOT, *parts)) as f:
        return f.read()


def test_every_strategy_documented_in_api_md():
    from repro.core import registry

    api = _read("docs", "api.md")
    missing = [s for s in registry.available() if s not in api]
    assert not missing, (
        f"strategies {missing} are registered in repro.core.registry but "
        f"never mentioned in docs/api.md — document them in the protocol/"
        f"migration/metrics tables")


# ---------------------------------------------------------------------------
# Fault taxonomy / recovery-log schema <-> docs
# ---------------------------------------------------------------------------


def test_every_fault_kind_documented_in_robustness_md():
    from repro.core import faults

    doc = _read("docs", "robustness.md")
    missing = [k for k in faults.FAULT_KINDS if f"`{k}`" not in doc]
    assert not missing, (
        f"fault kinds {missing} exist in repro.core.faults.FAULT_KINDS but "
        f"are not documented in docs/robustness.md (the fault taxonomy "
        f"table)")


def test_every_recovery_event_documented_in_api_md():
    from repro.core import faults

    api = _read("docs", "api.md")
    missing = [e for e in faults.RECOVERY_EVENTS if f"`{e}`" not in api]
    assert not missing, (
        f"recovery-log events {missing} exist in "
        f"repro.core.faults.RECOVERY_EVENTS but are not documented in "
        f"docs/api.md (the 'Recovery events' schema table)")


# ---------------------------------------------------------------------------
# Telemetry registries <-> docs/observability.md
# ---------------------------------------------------------------------------


def test_every_span_name_documented_in_observability_md():
    from repro.obs.trace import SPAN_NAMES

    doc = _read("docs", "observability.md")
    missing = [s for s in SPAN_NAMES if f"`{s}`" not in doc]
    assert not missing, (
        f"span names {missing} exist in repro.obs.trace.SPAN_NAMES but "
        f"are not documented in docs/observability.md (the span taxonomy "
        f"table)")


def test_every_metric_name_documented_in_observability_md():
    from repro.obs.metrics import METRIC_NAMES

    doc = _read("docs", "observability.md")
    missing = [m for m in METRIC_NAMES if f"`{m}`" not in doc]
    assert not missing, (
        f"metric names {missing} exist in repro.obs.metrics.METRIC_NAMES "
        f"but are not documented in docs/observability.md (the metric "
        f"schema table)")


# ---------------------------------------------------------------------------
# BENCH_*.json <-> docs/perf.md schema section
# ---------------------------------------------------------------------------


def _bench_files():
    out = []
    for d in (ROOT, os.path.join(ROOT, "experiments", "bench")):
        if not os.path.isdir(d):
            continue
        for name in sorted(os.listdir(d)):
            if name.startswith("BENCH_") and name.endswith(".json"):
                out.append(os.path.join(d, name))
    return out


def _normalize(key: str) -> str:
    return re.sub(r"\d+", "N", key)


def test_bench_files_exist():
    names = {os.path.basename(p) for p in _bench_files()}
    assert {"BENCH_loop.json", "BENCH_events.json",
            "BENCH_spmd.json", "BENCH_recovery.json",
            "BENCH_serve.json", "BENCH_router.json",
            "BENCH_obs.json"} <= names


@pytest.mark.parametrize("path", _bench_files(),
                         ids=lambda p: os.path.relpath(p, ROOT))
def test_every_bench_key_documented_in_perf_md(path):
    perf = _read("docs", "perf.md")
    with open(path) as f:
        payload = json.load(f)
    missing = [k for k in payload
               if k not in perf and _normalize(k) not in perf]
    assert not missing, (
        f"{os.path.relpath(path, ROOT)} keys {missing} are not documented "
        f"in docs/perf.md (Bench JSON schema section); add the key or its "
        f"digit-normalized pattern ({[_normalize(k) for k in missing]})")


# ---------------------------------------------------------------------------
# Markdown link + anchor checker
# ---------------------------------------------------------------------------


_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_CODE_FENCE = re.compile(r"```.*?```", re.S)


def _md_files():
    files = [os.path.join(ROOT, n) for n in sorted(os.listdir(ROOT))
             if n.endswith(".md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += [os.path.join(docs, n) for n in sorted(os.listdir(docs))
                  if n.endswith(".md")]
    return files


def _slugify(header: str) -> str:
    """GitHub anchor slug: strip markdown/punctuation, lowercase,
    spaces -> hyphens."""
    h = re.sub(r"[`*_]", "", header.strip())
    h = h.lower()
    h = "".join(c for c in h if c in string.ascii_lowercase + string.digits
                + " -")
    return h.replace(" ", "-")


def _anchors(md_text: str):
    return {_slugify(m.group(1))
            for m in re.finditer(r"^#+\s+(.+)$", md_text, re.M)}


@pytest.mark.parametrize("path", _md_files(),
                         ids=lambda p: os.path.relpath(p, ROOT))
def test_markdown_links_resolve(path):
    text = _CODE_FENCE.sub("", _read(os.path.relpath(path, ROOT)))
    problems = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, anchor = target.partition("#")
        if target:
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                problems.append(f"broken link: {m.group(1)}")
                continue
        else:
            resolved = path
        if anchor:
            if not resolved.endswith(".md"):
                continue
            with open(resolved) as f:
                if anchor not in _anchors(f.read()):
                    problems.append(f"broken anchor: {m.group(1)}")
    assert not problems, "\n".join(
        [f"in {os.path.relpath(path, ROOT)}:"] + problems)
