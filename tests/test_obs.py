"""Telemetry layer: tracer, metrics registry, measured straggler tails.

Covers the docs/observability.md contracts:

* the disabled-tracing path is a no-op (< 2% of the chunked loop);
* span nesting survives a Chrome-trace export round-trip;
* the windowed-quantile extraction matches the legacy SLO estimator;
* ``EmpiricalLatencyModel`` rides dynamic_backup's state_dict through a
  real checkpoint save/restore;
* the engine-level wall-clock SLO gate trips under a slowdown fault;
* latency_source='measured' closes the loop on the SPMD backend
  (subprocess, forced host devices — conftest keeps 1 device here).
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.obs import (NULL, SPAN_NAMES, METRIC_NAMES,
                       EmpiricalLatencyModel, MetricsRegistry, Tracer,
                       WindowedQuantile, as_tracer, load_jsonl, load_trace,
                       span_tree, windowed_quantile)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Windowed quantile: the estimator extracted from serve/slo.py
# ---------------------------------------------------------------------------


def test_windowed_quantile_matches_percentile():
    rng = np.random.default_rng(0)
    vals = list(rng.exponential(1.0, size=200))
    for q in (50.0, 95.0, 99.0):
        assert windowed_quantile(vals, q) == pytest.approx(
            float(np.percentile(np.asarray(vals, np.float64), q)))


def test_windowed_quantile_warmup_default():
    assert windowed_quantile([], 99.0) == 0.0
    assert windowed_quantile([1.0, 2.0], 99.0, min_samples=8,
                             default=-1.0) == -1.0
    # the router's hedge-threshold convention: -inf under warmup so
    # max(est, hedge_after) degrades to the static threshold
    assert windowed_quantile([], 95.0,
                             default=float("-inf")) == float("-inf")


def test_windowed_quantile_class_roundtrip():
    wq = WindowedQuantile(window=8, quantile=95.0, min_samples=2)
    for v in range(20):
        wq.observe(float(v))
    assert len(wq.values) == 8                     # FIFO trimmed
    est = wq.estimate()
    w2 = WindowedQuantile(window=8, quantile=95.0, min_samples=2)
    w2.load_state_dict(wq.state_dict())
    assert w2.estimate() == est


# ---------------------------------------------------------------------------
# Tracer: spans, ring buffer, Chrome-trace export
# ---------------------------------------------------------------------------


def test_span_registry_well_formed():
    assert len(set(SPAN_NAMES)) == len(SPAN_NAMES)
    assert len(set(METRIC_NAMES)) == len(METRIC_NAMES)
    for name in SPAN_NAMES + METRIC_NAMES:
        cat, _, rest = name.partition("/")
        assert cat in ("train", "spmd", "serve", "router") and rest, name


def test_tracer_export_roundtrip_and_nesting(tmp_path):
    tr = Tracer()
    with tr.span("train/chunk", k=4):
        with tr.span("train/data_wait"):
            time.sleep(0.001)
        with tr.span("train/device_wait"):
            time.sleep(0.001)
    tr.instant("router/hedge", rid=7)
    tr.counter("train/steps", 4)
    path = tmp_path / "trace.json"
    tr.export(str(path))

    data = load_trace(str(path))
    assert data["otherData"]["dropped"] == 0
    phases = {e["ph"] for e in data["traceEvents"]}
    assert phases == {"X", "i", "C"}
    roots = span_tree(data["traceEvents"])
    assert [r["name"] for r in roots] == ["train/chunk"]
    kids = [c["name"] for c in roots[0]["children"]]
    assert kids == ["train/data_wait", "train/device_wait"]
    assert roots[0]["args"] == {"k": 4}


def test_tracer_ring_drops_oldest(tmp_path):
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("serve/evict", i=i)
    assert len(tr) == 4 and tr.dropped == 6
    assert [e["args"]["i"] for e in tr.events] == [6, 7, 8, 9]
    path = tmp_path / "t.json"
    tr.export(str(path))
    assert load_trace(str(path))["otherData"]["dropped"] == 6


def test_load_trace_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"name": "x", "ph": "X",
                                                "ts": 0.0}]}))
    with pytest.raises(ValueError, match="dur"):
        load_trace(str(bad))
    bad.write_text(json.dumps([1, 2]))
    with pytest.raises(ValueError, match="traceEvents"):
        load_trace(str(bad))


def test_null_tracer_is_shared_noop():
    assert as_tracer(None) is NULL and not NULL.enabled
    s1, s2 = NULL.span("train/chunk", k=1), NULL.span("serve/decode")
    assert s1 is s2                                # no per-call allocation
    with s1:
        pass
    NULL.instant("router/timeout")
    NULL.export("/nonexistent/dir/never_written.json")


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_metrics_registry_kinds_and_jsonl(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve/completed").inc(3)
    reg.gauge("train/wall_time_s").set(1.5)
    h = reg.histogram("router/latency")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.summary()["count"] == 4
    assert h.summary()["mean"] == pytest.approx(2.5)
    assert h.quantile(50.0) == pytest.approx(2.5)
    with pytest.raises(ValueError, match="counter"):
        reg.gauge("serve/completed")               # kind mismatch

    path = tmp_path / "metrics.jsonl"
    reg.dump_jsonl(str(path))
    rows = load_jsonl(str(path))
    by_name = {r["name"]: r for r in rows}
    assert by_name["serve/completed"]["value"] == 3
    assert by_name["router/latency"]["p99"] == pytest.approx(
        float(np.percentile([1.0, 2.0, 3.0, 4.0], 99.0)))


# ---------------------------------------------------------------------------
# EmpiricalLatencyModel: measured tails for dynamic_backup
# ---------------------------------------------------------------------------


def test_empirical_latency_model_records_and_samples():
    m = EmpiricalLatencyModel(num_workers=3, window=16)
    rng = np.random.default_rng(0)
    for _ in range(8):
        m.record([1.0, 2.0, np.inf])               # worker 2 dead this row
    assert m.rows == 8 and m.dropped == 8
    out = m.sample(rng, (5, 3))
    assert out.shape == (5, 3) and np.isfinite(out).all()
    assert set(np.unique(out[:, 0])) <= {1.0}
    # worker 2 never contributed a finite sample: pooled fallback
    assert set(np.unique(out[:, 2])) <= {1.0, 2.0}
    assert m.quantile(50.0, worker=1) == pytest.approx(2.0)

    m2 = EmpiricalLatencyModel(num_workers=3)
    m2.load_state_dict(m.state_dict())
    assert m2.rows == 8
    assert m2.mean_row() == pytest.approx(m.mean_row())


def test_empirical_latency_model_fallback_before_data():
    m = EmpiricalLatencyModel(num_workers=2, fallback_s=0.5)
    out = m.sample(np.random.default_rng(0), (4, 2))
    assert (out == 0.5).all()


# ---------------------------------------------------------------------------
# dynamic_backup measured mode
# ---------------------------------------------------------------------------


def test_dynamic_backup_measured_state_roundtrip():
    from repro.core.coordination import DynamicBackup

    db = DynamicBackup(4, 2, window=4, latency_source="measured")
    rng = np.random.default_rng(0)
    for _ in range(6):
        db.observe_measured(rng.exponential(1.0, size=6))
    sd = db.state_dict()
    assert sd["latency_source"] == "measured"
    assert sd["measured"]["rows"] == 6

    db2 = DynamicBackup(4, 2, window=4, latency_source="measured")
    db2.load_state_dict(sd)
    assert db2.n == db.n and db2.measured.rows == 6

    # pre-telemetry checkpoints (no 'measured' key) still load
    db3 = DynamicBackup(4, 2, window=4, latency_source="measured")
    db3.load_state_dict({"n": 5, "history": sd["history"]})
    assert db3.n == 5 and db3.measured.rows == 0


def test_dynamic_backup_sim_mode_rejects_measured_feed():
    from repro.core.coordination import DynamicBackup

    db = DynamicBackup(4, 2)
    assert db.latency_source == "sim" and db.measured is None
    with pytest.raises(RuntimeError, match="measured"):
        db.observe_measured(np.ones(6))
    with pytest.raises(ValueError, match="latency_source"):
        DynamicBackup(4, 2, latency_source="oracle")


# ---------------------------------------------------------------------------
# Trainer integration: spans, phases, measured feed through a checkpoint
# ---------------------------------------------------------------------------


def _train_cfg(tmp_path, **kw):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import tiny_lm_config
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    OptimizerConfig, ShapeConfig,
                                    TrainConfig)
    agg = dict(strategy="full_sync", num_workers=4)
    agg.update(kw.pop("agg", {}))
    defaults = dict(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 16, 8, "train"),
        aggregation=AggregationConfig(**agg),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0),
        log_every=100, chunk_size=4, straggler_backend="host")
    defaults.update(kw)
    return TrainConfig(**defaults)


def test_trainer_traced_run_emits_spans_and_phases(tmp_path):
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    tracer, reg = Tracer(), MetricsRegistry()
    tr = Trainer(_train_cfg(tmp_path), latency=Uniform(1.0, 2.0),
                 tracer=tracer, metrics=reg)
    tr.init_state()
    res = tr.run(8)

    names = {e["name"] for e in tracer.events}
    assert names <= set(SPAN_NAMES)
    assert {"train/chunk", "train/device_wait",
            "train/data_wait"} <= names
    roots = span_tree(list(tracer.events))
    chunk_roots = [r for r in roots if r["name"] == "train/chunk"]
    assert len(chunk_roots) == 2                   # 8 steps / chunk_size 4
    assert res.wall_time_s > 0
    assert set(res.phase_times) == {"dispatch_s", "data_s", "ckpt_s"}
    assert res.phase_times["dispatch_s"] > 0
    assert reg.counter("train/steps").value == 8
    assert reg.histogram("train/chunk_time_s").count == 2


def test_trainer_untraced_result_has_no_phase_breakdown(tmp_path):
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    tr = Trainer(_train_cfg(tmp_path), latency=Uniform(1.0, 2.0))
    tr.init_state()
    res = tr.run(4)
    assert res.phase_times == {}                   # observability off
    assert res.wall_time_s > 0                     # wall clock is free


def test_measured_feed_rides_checkpoint(tmp_path):
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    cfg = _train_cfg(tmp_path, agg=dict(
        strategy="dynamic_backup", num_workers=4, backup_workers=2,
        dynamic_window=4, latency_source="measured"))
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    tr.run(8)
    assert tr.strategy.measured.rows == 2          # one row per chunk
    path = tr.save_checkpoint()
    assert os.path.exists(path)

    tr2 = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr2.init_state()
    tr2.restore_checkpoint()
    assert tr2.strategy.measured.rows == 2
    assert tr2.strategy.measured.mean_row() == pytest.approx(
        tr.strategy.measured.mean_row())
    assert tr2.strategy.n == tr.strategy.n


def test_null_path_overhead_under_two_percent(tmp_path):
    """ISSUE acceptance: disabled tracing costs < 2% of the chunked loop.

    Non-flaky by construction: the no-op hook cost is measured in a
    tight loop (sub-µs) and compared against the *measured* wall time of
    one chunk_size=32 fused dispatch (tens of ms) — a ~3 orders of
    magnitude margin."""
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    tr = Trainer(_train_cfg(tmp_path, chunk_size=32),
                 latency=Uniform(1.0, 2.0))
    tr.init_state()
    tr.run(32)                                     # compile + warm
    t0 = time.perf_counter()
    tr.run(32)
    chunk_s = time.perf_counter() - t0

    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with NULL.span("train/chunk"):
            pass
    hook_s = (time.perf_counter() - t0) / n
    hooks_per_chunk = 5        # chunk + data_wait + device_wait + 2 clock
    overhead = hooks_per_chunk * hook_s / chunk_s
    assert overhead < 0.02, (
        f"no-op tracing hooks cost {overhead:.2%} of a chunk "
        f"({hook_s * 1e6:.2f}us/hook, {chunk_s * 1e3:.1f}ms/chunk)")


# ---------------------------------------------------------------------------
# Wall-clock SLO gate under a slowdown fault (serve engine)
# ---------------------------------------------------------------------------


def test_wall_clock_slo_trips_under_slowdown():
    import jax

    from repro import configs
    from repro.models import get_model
    from repro.serve.engine import ServeEngine
    from repro.serve.slo import SLOConfig
    from repro.serve.trace import Request

    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def req(rid, arrival):
        return Request(rid=rid, arrival=arrival,
                       prompt=rng.integers(0, cfg.vocab_size, size=4,
                                           dtype=np.int32).astype(np.int32),
                       max_new=5)

    kw = dict(num_slots=2, page_size=4, max_prompt_len=8, max_new_cap=8,
              clock="wall")
    warm = [req(100 + i, 0.0) for i in range(2)]   # pay jit compile
    early = [req(i, 0.0) for i in range(6)]

    eng = ServeEngine(cfg, params, **kw)
    eng.run(warm)
    base = eng.run(early)
    p99_base = base.metrics["p99_latency"]
    assert base.metrics["completed"] == len(early)

    # calibrate the SLO to 3x the healthy tail and slow decode 30x: the
    # early burst's measured latencies blow through the target, and the
    # late burst arrives only after the slowed early completions (its
    # arrival scales with the measured baseline, so there is no
    # machine-speed race) — the wall-clock gate must have tripped by then
    t_late = max(2.0, 60.0 * p99_base)
    trace = early + [req(6 + i, t_late) for i in range(10)]
    slo = SLOConfig(target_p99=max(3.0 * p99_base, 1e-3), mode="shed",
                    window=32, min_samples=4, probe_every=0)
    hit_eng = ServeEngine(cfg, params, slo=slo,
                          faults="slowdown@1:x30:d1000000", **kw)
    hit_eng.run(warm)
    hit = hit_eng.run(trace)
    assert hit.metrics["slo_trips"] >= 1
    assert hit.metrics["rejected_slo_shed"] >= 1
    assert hit.metrics["completed"] + hit.metrics["rejected"] == len(trace)
    assert hit.metrics["wall_time_s"] > 0


# ---------------------------------------------------------------------------
# Measured mode on the SPMD backend (subprocess: forced host devices)
# ---------------------------------------------------------------------------


def test_measured_dynamic_backup_on_spmd_backend():
    code = r"""
import numpy as np
from benchmarks.common import tiny_lm_config
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig,
                                ShapeConfig, TrainConfig)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

import tempfile
with tempfile.TemporaryDirectory() as tmp:
    cfg = TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 16, 12, "train"),
        aggregation=AggregationConfig(
            strategy="dynamic_backup", num_workers=4, backup_workers=2,
            dynamic_window=4, latency_source="measured"),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=tmp, every_steps=0),
        execution=ExecutionConfig(backend="spmd", mesh_data=2),
        log_every=100, chunk_size=4, straggler_backend="host")
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    res = tr.run(8)
    assert tr._spmd, "expected the SPMD execution backend"
    assert tr.strategy.measured.rows == 2, tr.strategy.measured.rows
    row = tr.strategy.measured.mean_row()
    assert np.isfinite(row).all() and (np.asarray(row) > 0).all()
    sd = tr.strategy.state_dict()
    assert sd["latency_source"] == "measured"
    assert sd["measured"]["rows"] == 2
    print("measured-on-spmd OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, root, env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "measured-on-spmd OK" in out.stdout
