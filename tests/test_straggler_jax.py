"""JAX latency samplers: distribution equivalence with the numpy models,
and select_jax == select on identical arrivals."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, straggler, straggler_jax


def _np_samples(model, n, workers=8, seed=0):
    rng = np.random.RandomState(seed)
    return model.sample(rng, (n, workers))


def _jax_samples(model, n, workers=8, seed=0):
    fn = straggler_jax.sampler_for(model)
    return np.asarray(fn(jax.random.PRNGKey(seed), (n, workers)))


MODELS = [
    straggler.Uniform(1.0, 2.0),
    straggler.LogNormal(median=1.4, sigma=0.15),
    straggler.PaperCalibrated(),
    straggler.DeterministicStragglers(slow_workers=(2,), slowdown=5.0),
]


@pytest.mark.parametrize("model", MODELS, ids=lambda m: type(m).__name__)
def test_distribution_equivalence(model):
    """Moments and quantiles agree between the numpy and jax samplers."""
    a = _np_samples(model, 4000).ravel()
    b = _jax_samples(model, 4000).ravel()
    assert np.all(b > 0)
    assert b.mean() == pytest.approx(a.mean(), rel=0.08)
    for q in (0.1, 0.5, 0.9):
        assert np.quantile(b, q) == pytest.approx(np.quantile(a, q), rel=0.05)


def test_paper_calibrated_tail_and_cap():
    m = straggler.PaperCalibrated()
    s = _jax_samples(m, 30000, workers=4).ravel()
    assert s.max() <= m.cap + 1e-5
    tail_frac = np.mean(s > m.base + 5.0)
    assert 0.5 * m.p_tail < tail_frac < 2.5 * m.p_tail


def test_deterministic_stragglers_slow_worker():
    m = straggler.DeterministicStragglers(slow_workers=(1,), slowdown=50.0)
    s = _jax_samples(m, 500, workers=4)
    assert s[:, 1].mean() > 10 * s[:, 0].mean()


def test_sampler_for_unknown_model_raises():
    class Weird(straggler.LatencyModel):
        pass

    with pytest.raises(NotImplementedError):
        straggler_jax.sampler_for(Weird())


def test_register_sampler_extension():
    class Constant(straggler.LatencyModel):
        pass

    straggler_jax.register_sampler(
        Constant, lambda model, key, shape: jnp.full(shape, 2.5))
    out = straggler_jax.sampler_for(Constant())(jax.random.PRNGKey(0), (3,))
    np.testing.assert_allclose(np.asarray(out), 2.5)


def test_step_arrivals_dead_worker_inf():
    arr = straggler_jax.step_arrivals(
        straggler.Uniform(1.0, 2.0), jax.random.PRNGKey(0), 3, 4,
        dead=jnp.asarray([False, True, False, False]))
    arr = np.asarray(arr)
    assert np.isinf(arr[1])
    assert np.all(np.isfinite(np.delete(arr, 1)))


@pytest.mark.parametrize("strategy", [
    aggregation.FullSync(8),
    aggregation.BackupWorkers(6, 2),
    aggregation.Timeout(8, 0.5),
], ids=lambda s: type(s).__name__)
def test_select_jax_matches_select(strategy):
    rng = np.random.RandomState(0)
    for _ in range(25):
        arrivals = rng.uniform(0.5, 5.0, size=8)
        mask_np, t_np = strategy.select(arrivals)
        mask_j, t_j = strategy.select_jax(jnp.asarray(arrivals))
        np.testing.assert_array_equal(mask_np, np.asarray(mask_j))
        assert float(t_j) == pytest.approx(t_np, rel=1e-6)


def test_select_jax_backup_with_inf_arrivals():
    """Dead (inf) workers land last in the sort and are never selected
    while enough live workers exist."""
    s = aggregation.BackupWorkers(3, 2)
    arrivals = jnp.asarray([1.0, jnp.inf, 0.5, 2.0, 0.7])
    mask, t = s.select_jax(arrivals)
    mask = np.asarray(mask)
    assert not mask[1]
    assert mask.sum() == 3
    assert float(t) == pytest.approx(1.0)


def test_select_jax_is_traceable():
    s = aggregation.BackupWorkers(3, 1)
    f = jax.jit(s.select_jax)
    mask, t = f(jnp.asarray([3.0, 1.0, 2.0, 4.0]))
    assert np.asarray(mask).sum() == 3
    assert float(t) == pytest.approx(3.0)
