"""TP-sharded decode, exercised in subprocesses with
xla_force_host_platform_device_count (the main test process keeps 1 device
per the dry-run contract).

The acceptance bar for the serve subsystem: a checkpoint trained (here: a
short sim run) and restored through the checkpoint->serve bridge decodes
token-for-token identically with ``mesh_model=2`` and with ``mesh_model=1``
— TP sharding may never change what gets served."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_tp_checkpoint_serves_token_identically(tmp_path):
    """Train a few sim steps, checkpoint, restore via restore_params, then
    serve the same trace with mesh_model=2 and mesh_model=1: identical
    tokens per request, and the TP engine really shards (plan resolves)."""
    run_py(r"""
import numpy as np
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.serve import ServeEngine, TraceConfig, make_trace, restore_params
from repro.train.loop import Trainer

cfg = configs.get_smoke_config("qwen3-0.6b")
tcfg = TrainConfig(
    model=cfg, shape=ShapeConfig("tiny", 16, 8, "train"),
    aggregation=AggregationConfig(strategy="full_sync", num_workers=2),
    optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                              scale_lr_with_workers=False),
    checkpoint=CheckpointConfig(directory=%r, every_steps=100),
    log_every=10)
tr = Trainer(tcfg)
tr.init_state()
tr.run(3)
tr.save_checkpoint()

params, manifest = restore_params(%r, cfg)
assert manifest["step"] == 3, manifest

trace = make_trace(TraceConfig(num_requests=4, rate=8.0, prompt_len_min=2,
                               prompt_len_max=8, max_new_min=3, max_new_max=6,
                               vocab=cfg.vocab_size, seed=0))
kw = dict(num_slots=2, page_size=4, max_prompt_len=8, max_new_cap=6,
          clock="virtual")
tp = ServeEngine(cfg, params, mesh_model=2, **kw)
assert tp.tp_plan is not None and (
    tp.tp_plan.attn or tp.tp_plan.ffn or tp.tp_plan.vocab), tp.tp_plan
rep_tp = tp.run(trace)
rep_1 = ServeEngine(cfg, params, **kw).run(trace)
assert rep_tp.metrics["completed"] == 4
assert rep_tp.tokens_by_rid() == rep_1.tokens_by_rid()
print("TP_PARITY_OK")
""" % (str(tmp_path), str(tmp_path)))


def test_tp_engine_requires_devices():
    """mesh_model larger than the device count is a clear error, not a
    silent fallback (1 forced device)."""
    run_py(r"""
import jax
from repro import configs
from repro.models import get_model
from repro.serve import ServeEngine

cfg = configs.get_smoke_config("qwen3-0.6b")
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
try:
    ServeEngine(cfg, params, mesh_model=4, clock="virtual")
except ValueError as e:
    assert "devices" in str(e)
    print("REJECTED_OK")
else:
    raise AssertionError("mesh_model=4 on 1 device should fail")
""", devices=1)
