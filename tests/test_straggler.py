"""Latency models + order statistics (paper Figs. 3/4 machinery)."""
import numpy as np
import pytest

from repro.core import straggler
from repro.core.aggregation import BackupWorkers, FullSync
from repro.core.events import StragglerSimulator, mean_iteration_time


def test_models_positive_and_shaped():
    rng = np.random.RandomState(0)
    for model in [straggler.PaperCalibrated(), straggler.LogNormal(),
                  straggler.Uniform(),
                  straggler.DeterministicStragglers(slow_workers=(1,))]:
        t = model.sample(rng, (50, 10))
        assert t.shape == (50, 10)
        assert (t > 0).all()


def test_order_stats_monotone():
    rng = np.random.RandomState(1)
    lat = straggler.PaperCalibrated().sample(rng, (500, 100))
    mean_k, med_k = straggler.mean_median_time_to_k(lat)
    assert (np.diff(mean_k) >= -1e-9).all()
    assert (np.diff(med_k) >= -1e-9).all()


def test_paper_calibration_shape():
    """Fig. 4's signature: flat middle (~1.4-1.8s), exploding tail."""
    rng = np.random.RandomState(2)
    lat = straggler.PaperCalibrated().sample(rng, (3000, 100))
    mean_k, _ = straggler.mean_median_time_to_k(lat)
    assert 1.2 < mean_k[49] < 1.9          # k=50 in the flat region
    assert mean_k[99] > 4 * mean_k[49]     # final gradient blows up
    assert lat.max() <= 310.0              # paper's observed cap


def test_cdf_of_time_to_k():
    rng = np.random.RandomState(3)
    lat = straggler.PaperCalibrated().sample(rng, (1000, 100))
    grid = np.linspace(0, 6, 20)
    cdf98 = straggler.cdf_of_time_to_k(lat, 98, grid)
    cdf100 = straggler.cdf_of_time_to_k(lat, 100, grid)
    assert (np.diff(cdf98) >= 0).all()
    # the 98th gradient arrives sooner than the 100th in distribution
    assert (cdf98 >= cdf100 - 1e-9).all()


def test_deterministic_straggler_hits_selection():
    rng = np.random.RandomState(4)
    model = straggler.DeterministicStragglers(slow_workers=(3,), slowdown=50)
    lat = model.sample(rng, (200, 8))
    st = BackupWorkers(6, 2)
    dropped = [not st.select(a)[0][3] for a in lat]
    assert np.mean(dropped) > 0.95         # the bad node is ~always dropped


def test_simulator_dead_worker_and_determinism():
    sim1 = StragglerSimulator(BackupWorkers(4, 2), straggler.Uniform(), seed=7)
    sim2 = StragglerSimulator(BackupWorkers(4, 2), straggler.Uniform(), seed=7)
    e1, e2 = sim1.next_event(), sim2.next_event()
    np.testing.assert_array_equal(e1.mask, e2.mask)
    assert e1.iteration_time == e2.iteration_time
    sim1.kill_worker(0)
    for _ in range(10):
        ev = sim1.next_event()
        assert not ev.mask[0]
        assert ev.mask.sum() == 4
    assert sim1.alive == 5


def test_mean_iteration_time_backup_below_fullsync():
    lat = straggler.PaperCalibrated()
    t_full = mean_iteration_time(FullSync(100), lat, iters=300)
    t_back = mean_iteration_time(BackupWorkers(96, 4), lat, iters=300)
    assert t_back < t_full
