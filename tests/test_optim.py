"""Optimizers vs hand-computed reference math; paper lr schedules; EMA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ema as ema_lib
from repro.optim import optimizers as opt_lib
from repro.optim import schedules


def _p():
    return {"a": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([[0.5]])}


def _g():
    return {"a": jnp.asarray([0.1, 0.2]), "b": jnp.asarray([[-0.3]])}


def test_sgd_math():
    opt = opt_lib.sgd(schedules.constant(0.1))
    s = opt.init(_p())
    new, s, _ = opt.apply(_p(), _g(), s, jnp.asarray(0))
    np.testing.assert_allclose(new["a"], [1.0 - 0.01, -2.0 - 0.02], rtol=1e-6)


def test_momentum_math():
    opt = opt_lib.momentum(schedules.constant(0.1), beta=0.9)
    p, s = _p(), None
    s = opt.init(p)
    p, s, _ = opt.apply(p, _g(), s, jnp.asarray(0))
    p, s, _ = opt.apply(p, _g(), s, jnp.asarray(1))
    # m1 = g; m2 = 0.9 g + g = 1.9 g; p = p0 - lr(g + 1.9g)
    np.testing.assert_allclose(p["a"][0], 1.0 - 0.1 * (0.1 + 0.19), rtol=1e-5)
    np.testing.assert_allclose(p["a"][1], -2.0 - 0.1 * (0.2 + 0.38), rtol=1e-5)


def test_rmsprop_momentum_math():
    """The paper's optimizer (TF-style RMSProp with momentum)."""
    opt = opt_lib.rmsprop_momentum(schedules.constant(0.5), decay=0.9,
                                   mom=0.9, eps=1e-8)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([1.0])}
    s = opt.init(p)
    p1, s, _ = opt.apply(p, g, s, jnp.asarray(0))
    ms = 0.1
    mom = 0.5 * 1.0 / np.sqrt(ms + 1e-8)
    np.testing.assert_allclose(p1["w"], 2.0 - mom, rtol=1e-5)
    p2, s, _ = opt.apply(p1, g, s, jnp.asarray(1))
    ms2 = 0.9 * ms + 0.1
    mom2 = 0.9 * mom + 0.5 / np.sqrt(ms2 + 1e-8)
    np.testing.assert_allclose(p2["w"], p1["w"] - mom2, rtol=1e-5)


def test_adam_math():
    opt = opt_lib.adam(schedules.constant(0.1))
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.5])}
    s = opt.init(p)
    p1, _, _ = opt.apply(p, g, s, jnp.asarray(0))
    # bias-corrected first step: update = lr * g/|g| = lr (for eps->0)
    np.testing.assert_allclose(p1["w"], 1.0 - 0.1, rtol=1e-4)


def test_adagrad_math():
    opt = opt_lib.adagrad(schedules.constant(1.0))
    p = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([2.0])}
    s = opt.init(p)
    p1, s, _ = opt.apply(p, g, s, jnp.asarray(0))
    np.testing.assert_allclose(p1["w"], -1.0, rtol=1e-5)   # g/sqrt(g^2)
    p2, _, _ = opt.apply(p1, g, s, jnp.asarray(1))
    np.testing.assert_allclose(p2["w"], -1.0 - 2 / np.sqrt(8), rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}   # norm 5
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(norm, 5.0, rtol=1e-6)
    np.testing.assert_allclose(clipped["a"], [0.6], rtol=1e-5)
    unclipped, _ = opt_lib.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(unclipped["a"], [3.0], rtol=1e-6)


def test_paper_exponential_schedule():
    """A.3: gamma0 * beta^(t N / 2T)."""
    sched = schedules.exponential_decay(4.5, 0.94, steps_per_epoch=100,
                                        num_workers=50)
    assert float(sched(jnp.asarray(0))) == pytest.approx(4.5)
    t = 40
    expected = 4.5 * 0.94 ** (t * 50 / 200)
    assert float(sched(jnp.asarray(t))) == pytest.approx(expected, rel=1e-5)


def test_lr_scaling_rule():
    """A.3: gamma0 = 0.045 * N for Sync-Opt."""
    from repro.configs.base import OptimizerConfig
    cfg = OptimizerConfig(learning_rate=0.045, scale_lr_with_workers=True)
    sched = schedules.from_config(cfg, num_workers=100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(4.5)


def test_linear_anneal():
    sched = schedules.linear_anneal(0.1, total_steps=100, anneal_from=50)
    assert float(sched(jnp.asarray(10))) == pytest.approx(0.1)
    assert float(sched(jnp.asarray(75))) == pytest.approx(0.05)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.0)


def test_from_config_linear_anneal():
    """The A.1 MNIST recipe routes through OptimizerConfig."""
    from repro.configs.base import OptimizerConfig
    cfg = OptimizerConfig(learning_rate=0.1, scale_lr_with_workers=False,
                          linear_anneal_steps=100, linear_anneal_from=50)
    sched = schedules.from_config(cfg)
    assert float(sched(jnp.asarray(10))) == pytest.approx(0.1)
    assert float(sched(jnp.asarray(75))) == pytest.approx(0.05)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-7)


def test_warmup():
    sched = schedules.warmup(schedules.constant(1.0), 10)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(20))) == pytest.approx(1.0)


def test_ema_math_and_no_aliasing():
    p = {"w": jnp.asarray([1.0])}
    e = ema_lib.init(p)
    assert e["w"] is not p["w"]                 # donation-safety copy
    p2 = {"w": jnp.asarray([2.0])}
    e = ema_lib.update(e, p2, 0.9)
    np.testing.assert_allclose(e["w"], [0.9 * 1.0 + 0.1 * 2.0], rtol=1e-6)
