"""Fused bucketed reduce-then-psum: property tests against the jnp oracle.

The kernel under test (``repro.kernels.bucketed_reduce``) is the
collective half of the SPMD engine's aggregation: cut the flattened
[W, P] gradient stack into buckets, masked-reduce each in-shard, psum
per bucket, with monitoring scalars riding the last bucket. The oracle
is ``ref_masked_mean`` — the dense jnp reduction the property tests
hold every configuration to (random shapes, masks, bucket sizes, Pallas
blocks that do NOT divide the bucket, i.e. the padding edges).

Property tests use the ``hypothesis_stub`` shim: with hypothesis
installed (requirements-dev.txt, the CI path) they fuzz; without it they
report skipped while the deterministic edge-case tests still run.
"""
import numpy as np
import pytest

from hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels.bucketed_reduce import (bucket_bounds, ref_masked_mean,
                                           reduce_then_psum)


def _rand(seed, w, p):
    rng = np.random.default_rng(seed)
    grads = rng.standard_normal((w, p)).astype(np.float32)
    mask = (rng.random(w) < 0.7).astype(np.float32)
    return jnp.asarray(grads), jnp.asarray(mask)


def _assert_matches_ref(grads, mask, n_agg, **kw):
    agg, _ = reduce_then_psum(grads, mask, n_agg, **kw)
    ref = ref_masked_mean(grads, mask, n_agg)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# bucket_bounds
# ---------------------------------------------------------------------------


def test_bucket_bounds_edges():
    assert bucket_bounds(10, 0) == ((0, 10),)          # unbucketed
    assert bucket_bounds(10, 10) == ((0, 10),)         # bucket == total
    assert bucket_bounds(10, 11) == ((0, 10),)         # bucket > total
    assert bucket_bounds(10, 4) == ((0, 4), (4, 8), (8, 10))  # ragged last
    assert bucket_bounds(8, 4) == ((0, 4), (4, 8))     # exact
    assert bucket_bounds(0, 4) == ((0, 0),)            # empty flatten
    with pytest.raises(ValueError, match=">= 0"):
        bucket_bounds(-1, 4)


def test_bucket_bounds_cover_exactly():
    for total in (1, 7, 64, 100):
        for bucket in (1, 3, 8, 64, 200):
            bounds = bucket_bounds(total, bucket)
            assert bounds[0][0] == 0 and bounds[-1][1] == total
            for (a, b), (c, d) in zip(bounds, bounds[1:]):
                assert b == c and a < b


# ---------------------------------------------------------------------------
# Deterministic edges (always run, hypothesis or not)
# ---------------------------------------------------------------------------


def test_single_worker_shortcut_matches_ref():
    # W == 1 takes the scalar-rescale shortcut (no dot, no kernel) —
    # the common case when the mesh 'data' axis equals the worker count
    grads, _ = _rand(0, 1, 37)
    for mask_val in (0.0, 1.0):
        mask = jnp.asarray([mask_val])
        for bucket in (0, 16):
            _assert_matches_ref(grads, mask, 3, bucket=bucket,
                                use_kernel=True, interpret=True)


def test_kernel_padding_edges():
    # P=50 lanes, bucket=16 -> ragged last bucket of 2 lanes, block=8
    # does not divide it: backup_reduce's internal zero-padding edge
    grads, mask = _rand(1, 4, 50)
    _assert_matches_ref(grads, mask, 2, bucket=16, use_kernel=True,
                        interpret=True, block=8)
    # block larger than the whole bucket
    _assert_matches_ref(grads, mask, 2, bucket=6, use_kernel=True,
                        interpret=True, block=64)


def test_empty_flatten():
    grads, mask = _rand(2, 3, 0)
    agg, tail = reduce_then_psum(grads, mask, 2, tail=jnp.asarray([5.0, 7.0]),
                                 use_kernel=True, interpret=True)
    assert agg.shape == (0,)
    np.testing.assert_allclose(np.asarray(tail), [5.0, 7.0])


def test_tail_rides_last_bucket_without_perturbing_gradient():
    grads, mask = _rand(3, 5, 23)
    tail_in = jnp.asarray([2.5, -1.25, 9.0])
    plain, none_tail = reduce_then_psum(grads, mask, 4, bucket=8,
                                        use_kernel=False)
    agg, tail = reduce_then_psum(grads, mask, 4, bucket=8, tail=tail_in,
                                 use_kernel=False)
    assert none_tail is None
    np.testing.assert_allclose(np.asarray(agg), np.asarray(plain))
    np.testing.assert_allclose(np.asarray(tail), np.asarray(tail_in))


def test_mask_shape_mismatch_raises():
    grads, _ = _rand(4, 4, 10)
    with pytest.raises(ValueError, match="does not match the worker axis"):
        reduce_then_psum(grads, jnp.ones((3,)), 2)


def test_psum_path_on_single_device_mesh():
    """axis_name wired through shard_map on a (1, 1) mesh: the collective
    branch (psum per bucket, tail split after the psum) compiles and
    matches the oracle in-process — tier-1 coverage of the exact code
    the multi-device engine runs."""
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    grads, mask = _rand(5, 4, 33)
    tail_in = jnp.asarray([3.0, 4.0])

    from repro.distributed.spmd_engine import _shard_map
    from jax.sharding import PartitionSpec as P

    def body(g, m, t):
        return reduce_then_psum(g, m, 3, axis_name="data", bucket=10,
                                tail=t, use_kernel=False)

    fn = _shard_map(body, mesh, in_specs=(P(), P(), P()),
                    out_specs=(P(), P()))
    agg, tail = jax.jit(fn)(grads, mask, tail_in)
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(ref_masked_mean(grads, mask, 3)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(tail_in))


# ---------------------------------------------------------------------------
# Hypothesis properties: every configuration equals the oracle
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1), w=st.integers(1, 6),
       p=st.integers(1, 160), bucket=st.integers(0, 200))
@settings(max_examples=40, deadline=None)
def test_property_jnp_bucketing_matches_ref(seed, w, p, bucket):
    grads, mask = _rand(seed, w, p)
    n_agg = max(1, int(np.asarray(mask).sum()))
    _assert_matches_ref(grads, mask, n_agg, bucket=bucket, use_kernel=False)


@given(seed=st.integers(0, 2**31 - 1), w=st.integers(2, 5),
       p=st.integers(1, 120), bucket=st.integers(0, 130),
       block=st.integers(2, 48))
@settings(max_examples=25, deadline=None)
def test_property_kernel_bucketing_matches_ref(seed, w, p, bucket, block):
    # interpret-mode Pallas kernel per bucket, including blocks that do
    # not divide the (possibly ragged) bucket width — the padding edges
    grads, mask = _rand(seed, w, p)
    _assert_matches_ref(grads, mask, 2, bucket=bucket, use_kernel=True,
                        interpret=True, block=block)


@given(seed=st.integers(0, 2**31 - 1), p=st.integers(1, 100),
       bucket=st.integers(0, 110), e=st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_property_tail_passthrough(seed, p, bucket, e):
    grads, mask = _rand(seed, 3, p)
    rng = np.random.default_rng(seed + 1)
    tail_in = jnp.asarray(rng.standard_normal(e).astype(np.float32))
    agg, tail = reduce_then_psum(grads, mask, 2, bucket=bucket, tail=tail_in,
                                 use_kernel=False)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(tail_in),
                               rtol=1e-6, atol=0)
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(ref_masked_mean(grads, mask, 2)),
                               rtol=1e-5, atol=1e-6)
