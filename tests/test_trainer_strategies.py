"""Trainer integration across aggregation strategies + CLI smoke."""
import numpy as np
import pytest

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import PaperCalibrated, Uniform
from repro.train.loop import Trainer


def _cfg(tmp_path, strategy, workers=4, backups=2, deadline=1.5):
    return TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("t", 16, 24, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups,
                                      deadline_s=deadline),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.08,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0),
        log_every=5)


@pytest.mark.parametrize("strategy,backups", [("backup", 2),
                                              ("full_sync", 0),
                                              ("timeout", 0)])
def test_trainer_strategies_converge(tmp_path, strategy, backups):
    tr = Trainer(_cfg(tmp_path / strategy, strategy, backups=backups),
                 latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(25)
    losses = [m["loss"] for m in res.metrics]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert res.sim_time > 0


def test_backup_sim_time_below_fullsync(tmp_path):
    """Same machine count, same steps: backup strategy's simulated wall
    time must beat full sync under the heavy-tail model."""
    t_backup = Trainer(_cfg(tmp_path / "b", "backup", workers=4, backups=2),
                       latency=PaperCalibrated())
    t_backup.init_state()
    rb = t_backup.run(15)
    t_full = Trainer(_cfg(tmp_path / "f", "full_sync", workers=6, backups=0),
                     latency=PaperCalibrated())
    t_full.init_state()
    rf = t_full.run(15)
    assert rb.sim_time < rf.sim_time


def test_timeout_strategy_selects_variable_counts(tmp_path):
    tr = Trainer(_cfg(tmp_path, "timeout", workers=6, backups=0,
                      deadline=0.3), latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(15)
    counts = {m["selected"] for m in res.metrics}
    assert all(1 <= c <= 6 for c in counts)


def test_train_cli_smoke(tmp_path):
    from repro.launch import train as train_cli
    train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "6",
                    "--workers", "3", "--backups", "1",
                    "--batch-per-worker", "2", "--seq", "16",
                    "--ckpt", str(tmp_path), "--optimizer", "momentum",
                    "--lr", "0.05"])
    import os
    assert os.path.exists(os.path.join(str(tmp_path), "LATEST"))