"""Trainer integration across aggregation strategies + CLI smoke."""
import numpy as np
import pytest

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import PaperCalibrated, Uniform
from repro.train.loop import Trainer


def _cfg(tmp_path, strategy, workers=4, backups=2, deadline=1.5):
    return TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("t", 16, 24, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups,
                                      deadline_s=deadline),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.08,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0),
        log_every=5)


@pytest.mark.parametrize("strategy,backups", [("backup", 2),
                                              ("full_sync", 0),
                                              ("timeout", 0)])
def test_trainer_strategies_converge(tmp_path, strategy, backups):
    tr = Trainer(_cfg(tmp_path / strategy, strategy, backups=backups),
                 latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(25)
    losses = [m["loss"] for m in res.metrics]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert res.sim_time > 0


def test_backup_sim_time_below_fullsync(tmp_path):
    """Same machine count, same steps: backup strategy's simulated wall
    time must beat full sync under the heavy-tail model."""
    t_backup = Trainer(_cfg(tmp_path / "b", "backup", workers=4, backups=2),
                       latency=PaperCalibrated())
    t_backup.init_state()
    rb = t_backup.run(15)
    t_full = Trainer(_cfg(tmp_path / "f", "full_sync", workers=6, backups=0),
                     latency=PaperCalibrated())
    t_full.init_state()
    rf = t_full.run(15)
    assert rb.sim_time < rf.sim_time


def test_timeout_strategy_selects_variable_counts(tmp_path):
    tr = Trainer(_cfg(tmp_path, "timeout", workers=6, backups=0,
                      deadline=0.3), latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(15)
    counts = {m["selected"] for m in res.metrics}
    assert all(1 <= c <= 6 for c in counts)


def test_train_cli_smoke(tmp_path):
    from repro.launch import train as train_cli
    train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "6",
                    "--workers", "3", "--backups", "1",
                    "--batch-per-worker", "2", "--seq", "16",
                    "--ckpt", str(tmp_path), "--optimizer", "momentum",
                    "--lr", "0.05"])
    import os
    assert os.path.exists(os.path.join(str(tmp_path), "LATEST"))


# ---------------------------------------------------------------------------
# Event strategies through the same Trainer / run_experiment entry point
# ---------------------------------------------------------------------------


def _event_cfg(tmp_path, strategy, workers=4, steps=20, every=0, **agg_kw):
    from repro.configs.base import replace
    cfg = _cfg(tmp_path, strategy, workers=workers, backups=0)
    return replace(cfg,
                   aggregation=AggregationConfig(strategy=strategy,
                                                 num_workers=workers,
                                                 **agg_kw),
                   shape=ShapeConfig("t", 16, 4 * workers, "train"),
                   checkpoint=CheckpointConfig(directory=str(tmp_path),
                                               every_steps=every),
                   total_steps=steps, log_every=1)


@pytest.mark.parametrize("strategy,agg_kw", [("async", {}),
                                             ("softsync", {"softsync_c": 2})])
def test_trainer_event_strategies_run(tmp_path, strategy, agg_kw):
    from repro.train.loop import run_experiment
    cfg = _event_cfg(tmp_path / strategy, strategy, steps=20, **agg_kw)
    res = run_experiment(cfg, latency=Uniform(1.0, 2.0))
    assert res.steps == 20
    losses = [m["loss"] for m in res.metrics]
    assert all(np.isfinite(losses))
    assert res.sim_time > 0
    assert res.mean_staleness > 0          # async regimes apply stale grads
    # unified per-update metrics schema across both execution modes
    for m in res.metrics:
        for key in ("step", "loss", "sim_time", "selected", "staleness"):
            assert key in m


def test_mask_metrics_share_event_schema(tmp_path):
    tr = Trainer(_cfg(tmp_path, "backup", workers=4, backups=2),
                 latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(10)
    for m in res.metrics:
        for key in ("step", "loss", "sim_time", "selected", "staleness"):
            assert key in m
        assert m["staleness"] == 0.0       # synchronous: nothing is stale
    assert res.mean_staleness == 0.0


def test_timeout_reports_realized_mean_selected(tmp_path):
    """TrainResult carries the *actual* mean aggregated-worker count, not
    the effective_n() upper bound."""
    tr = Trainer(_cfg(tmp_path, "timeout", workers=6, backups=0,
                      deadline=0.05), latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(20)
    per_step = [m["selected"] for m in res.metrics]   # log_every=5 subset
    assert 1.0 <= res.mean_selected <= 6.0
    # a tight deadline under the heavy-tail model must drop someone
    assert res.mean_selected < tr.strategy.effective_n()
    assert min(per_step) >= 1


def test_event_checkpoint_resume_replay_exact(tmp_path):
    """Async resume from checkpoint replays the uninterrupted run exactly
    (worker copies + scheduler queue/RNG are checkpointed state)."""
    import jax
    from repro.train.loop import run_experiment
    cfg = _event_cfg(tmp_path / "full", "async", steps=20, every=8)
    full = run_experiment(cfg, latency=Uniform(1.0, 2.0))

    cfg2 = _event_cfg(tmp_path / "resume", "async", steps=20, every=8)
    t1 = Trainer(cfg2, latency=Uniform(1.0, 2.0))
    t1.init_state()
    t1.run(16)                              # checkpoints land at 8 and 16
    t2 = Trainer(cfg2, latency=Uniform(1.0, 2.0))
    t2.restore_checkpoint()
    assert t2.step == 16
    r2 = t2.run(4)
    a = np.asarray(jax.tree_util.tree_leaves(full.params)[0])
    b = np.asarray(jax.tree_util.tree_leaves(r2.params)[0])
    np.testing.assert_array_equal(a, b)
    tail_full = [m["staleness"] for m in full.metrics if m["step"] > 16]
    tail_res = [m["staleness"] for m in r2.metrics]
    assert tail_full == tail_res


def test_staleness_checkpoint_resume_mid_ramp(tmp_path):
    """The serial rig's old-gradient buffer is checkpointed state: resume
    in the middle of the ramp replays the uninterrupted run exactly."""
    import jax
    from repro.train.loop import run_experiment

    def cfg_at(p, every):
        return _event_cfg(p, "staleness", workers=1, steps=12, every=every,
                          staleness_tau=3, staleness_ramp_steps=10)

    full = run_experiment(cfg_at(tmp_path / "full", 0))
    cfg2 = cfg_at(tmp_path / "resume", 4)
    t1 = Trainer(cfg2)
    t1.init_state()
    t1.run(8)                               # buffer is non-empty mid-ramp
    t2 = Trainer(cfg2)
    t2.restore_checkpoint()
    r2 = t2.run(4)
    a = np.asarray(jax.tree_util.tree_leaves(full.params)[0])
    b = np.asarray(jax.tree_util.tree_leaves(r2.params)[0])
    np.testing.assert_array_equal(a, b)


def test_staleness_rejects_failure_injection(tmp_path):
    cfg = _event_cfg(tmp_path, "staleness", workers=1, steps=5,
                     staleness_tau=1)
    tr = Trainer(cfg)
    tr.init_state()
    with pytest.raises(ValueError, match="serial"):
        tr.run(5, kill_worker_at={2: 0})


def test_event_failure_injection(tmp_path):
    """A killed worker stops producing arrivals; the run still completes."""
    cfg = _event_cfg(tmp_path, "async", workers=4, steps=24)
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    res = tr.run(24, kill_worker_at={8: 0})
    assert res.steps == 24
    assert 0 in tr._event_dead


def test_train_cli_event_strategy_smoke(tmp_path):
    from repro.launch import train as train_cli
    train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "5",
                    "--strategy", "softsync", "--softsync-c", "2",
                    "--workers", "3", "--batch-per-worker", "2",
                    "--seq", "16", "--ckpt", str(tmp_path),
                    "--optimizer", "momentum", "--lr", "0.05"])
    import os
    assert os.path.exists(os.path.join(str(tmp_path), "LATEST"))


def test_train_cli_fused_event_smoke(tmp_path):
    """--chunk-size now applies to event strategies: the fused engine."""
    from repro.launch import train as train_cli
    train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "6",
                    "--strategy", "async", "--chunk-size", "4",
                    "--workers", "3", "--batch-per-worker", "2",
                    "--seq", "16", "--ckpt", str(tmp_path),
                    "--optimizer", "momentum", "--lr", "0.05"])
    import os
    assert os.path.exists(os.path.join(str(tmp_path), "LATEST"))


@pytest.mark.parametrize("argv", [
    ["--strategy", "full_sync", "--backups", "2"],
    ["--strategy", "async", "--deadline", "1.0"],
    ["--strategy", "backup", "--softsync-c", "2"],
    ["--strategy", "timeout", "--backups", "1"],
    ["--strategy", "async", "--straggler-backend", "device"],
    ["--strategy", "softsync", "--straggler-backend", "device"],
])
def test_train_cli_rejects_mismatched_args(argv):
    from repro.launch import train as train_cli
    with pytest.raises(SystemExit):
        train_cli.main(argv + ["--smoke", "--steps", "1"])