"""Hypothesis property tests for the aggregation strategies.

Skipped module-wide when ``hypothesis`` is not installed (it ships in
requirements-dev.txt); the deterministic fallbacks in test_aggregation.py
always run.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation


arrivals_strategy = st.lists(
    st.floats(min_value=0.01, max_value=500.0, allow_nan=False),
    min_size=5, max_size=32).map(np.array)


@given(arr=arrivals_strategy)
@settings(max_examples=30, deadline=None)
def test_backup_selects_fastest_n(arr):
    n = max(1, len(arr) - 2)
    s = aggregation.BackupWorkers(n, len(arr) - n)
    mask, t = s.select(arr)
    assert mask.sum() == n
    assert t == pytest.approx(np.sort(arr)[n - 1])
    # invariance: selected set == argsort prefix
    assert set(np.where(mask)[0]) == set(np.argsort(arr, kind="stable")[:n])


@given(arr=arrivals_strategy)
@settings(max_examples=30, deadline=None)
def test_fullsync_waits_for_max(arr):
    s = aggregation.FullSync(len(arr))
    mask, t = s.select(arr)
    assert mask.all()
    assert t == pytest.approx(arr.max())


@given(arr=arrivals_strategy, d=st.floats(0.0, 10.0))
@settings(max_examples=30, deadline=None)
def test_timeout_always_selects_at_least_one(arr, d):
    s = aggregation.Timeout(len(arr), d)
    mask, t = s.select(arr)
    assert mask.sum() >= 1
    assert mask[np.argmin(arr)]
    assert t <= arr.min() + d + 1e-9
