"""Serve subsystem: page pool invariants, trace replay, engine-vs-
greedy_generate token parity (fp/int8/sliding-window/MoE), the per-bucket
compile contract, continuous-vs-static scheduling, chaos wiring, and the
checkpoint->serve bridge. The TP decode path is covered by
test_serve_tp.py (subprocess, forced host devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model
from repro.models.attention import _dequantize_kv, _quantize_kv
from repro.serve import (PagePool, PoolConfig, ServeEngine, TraceConfig,
                         bucket_for, make_trace, pages_for, restore_params,
                         supports_paged)
from repro.train.serve_step import bucketed_max_len, greedy_generate


def _trace(n=5, *, seed=0, rate=4.0, max_prompt=12, max_new=6, vocab=128,
           min_new=2):
    return make_trace(TraceConfig(
        num_requests=n, rate=rate, prompt_len_min=2, prompt_len_max=max_prompt,
        max_new_min=min_new, max_new_max=max_new, vocab=vocab, seed=seed))


@pytest.fixture(scope="module")
def qwen():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_engine(qwen):
    cfg, _, params = qwen
    return ServeEngine(cfg, params, num_slots=3, page_size=4,
                      max_prompt_len=12, max_new_cap=8, clock="virtual")


# ---------------------------------------------------------------------------
# Page pool
# ---------------------------------------------------------------------------


def _pool_cfg(**kw):
    base = dict(num_layers=2, kv_heads=2, head_dim=4, num_pages=9,
                page_size=4, num_slots=2, max_pages_per_slot=4,
                quantized=False)
    base.update(kw)
    return PoolConfig(**base)


def test_pool_alloc_free_roundtrip():
    pool = PagePool(_pool_cfg())
    pool.alloc(0, 3)
    row = pool.page_table[0, :3]
    assert (row > 0).all(), "page 0 is the reserved trash page"
    assert len(set(row.tolist())) == 3
    assert (pool.page_table[0, 3:] == 0).all()
    pool.alloc(1, 4)
    assert not pool.can_alloc(2)          # 8 allocatable pages, 7 taken
    pool.free_slot(0)
    assert pool.can_alloc(3)
    assert (pool.page_table[0] == 0).all()


def test_pool_double_alloc_and_exhaustion():
    pool = PagePool(_pool_cfg(max_pages_per_slot=8))
    pool.alloc(0, 2)
    with pytest.raises(ValueError):
        pool.alloc(0, 1)                  # slot already holds pages
    with pytest.raises(MemoryError):
        pool.alloc(1, 8)                  # only 6 pages left
    with pytest.raises(ValueError):
        pool.alloc(1, 9)                  # > max_pages_per_slot


def test_pool_occupancy_accounting():
    pool = PagePool(_pool_cfg())
    pool.alloc(0, 4)
    pool.note_occupancy()
    assert pool.peak_pages == 4
    assert pool.mean_occupancy() == pytest.approx(4 / 8)


def test_pages_for_and_buckets():
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert bucket_for(3, floor=8) == 8
    assert bucket_for(9, floor=8) == 16
    assert bucket_for(16, floor=8) == 16
    with pytest.raises(ValueError):
        bucket_for(33, floor=8, cap=32)
    assert bucketed_max_len(17) == 32
    with pytest.raises(ValueError):
        bucketed_max_len(0)


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------


def test_trace_replayable_and_ordered():
    a, b = _trace(8, seed=3), _trace(8, seed=3)
    assert [(r.rid, r.arrival, r.max_new) for r in a] == \
        [(r.rid, r.arrival, r.max_new) for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    arr = [r.arrival for r in a]
    assert arr == sorted(arr)
    c = _trace(8, seed=4)
    assert [r.arrival for r in c] != arr


def test_trace_respects_bounds():
    t = _trace(16, max_prompt=9, max_new=5, vocab=32)
    assert all(2 <= r.prompt_len <= 9 for r in t)
    assert all(2 <= r.max_new <= 5 for r in t)
    assert all(0 <= int(r.prompt.max()) < 32 for r in t)


# ---------------------------------------------------------------------------
# Engine vs greedy_generate parity
# ---------------------------------------------------------------------------


def _reference_tokens(model, params, trace):
    out = {}
    for r in trace:
        toks = greedy_generate(model, params, jnp.asarray(r.prompt)[None, :],
                               r.max_new, r.prompt_len + r.max_new + 1)
        out[r.rid] = [int(t) for t in np.asarray(toks)[0]]
    return out


def _assert_parity(cfg, model, params, engine, trace):
    rep = engine.run(trace)
    assert rep.metrics["completed"] == len(trace)
    assert rep.tokens_by_rid() == _reference_tokens(model, params, trace)


def test_engine_matches_greedy_qwen(qwen, qwen_engine):
    cfg, model, params = qwen
    _assert_parity(cfg, model, params, qwen_engine,
                   _trace(5, vocab=cfg.vocab_size))


def test_engine_matches_greedy_sliding_window():
    """gemma3 interleaves sliding-window and global layers: decode past the
    window must mask paged positions exactly like the ring-buffer cache."""
    cfg = configs.get_smoke_config("gemma3-1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, num_slots=2, page_size=4,
                      max_prompt_len=8, max_new_cap=12, clock="virtual")
    # short prompts + 12 new tokens decode well past the smoke window
    trace = _trace(3, max_prompt=6, max_new=12, min_new=12,
                   vocab=cfg.vocab_size)
    _assert_parity(cfg, model, params, eng, trace)


def test_engine_matches_greedy_moe():
    cfg = configs.get_smoke_config("qwen2-moe-a2.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    eng = ServeEngine(cfg, params, num_slots=2, page_size=4,
                      max_prompt_len=8, max_new_cap=5, clock="virtual")
    _assert_parity(cfg, model, params, eng,
                   _trace(3, max_prompt=8, max_new=5, vocab=cfg.vocab_size))


def test_engine_kernel_path_matches_reference(qwen, qwen_engine):
    cfg, _, params = qwen
    eng_k = ServeEngine(cfg, params, num_slots=3, page_size=4,
                        max_prompt_len=12, max_new_cap=8, clock="virtual",
                        use_kernel=True, interpret=True)
    trace = _trace(3, vocab=cfg.vocab_size)
    assert eng_k.run(trace).tokens_by_rid() == \
        qwen_engine.run(trace).tokens_by_rid()


def test_unsupported_family_rejected():
    cfg = configs.get_smoke_config("deepseek-v2-lite-16b")   # MLA cache
    ok, why = supports_paged(cfg)
    assert not ok and why
    with pytest.raises(ValueError, match="paged serving unsupported"):
        ServeEngine(cfg, {}, clock="virtual")


# ---------------------------------------------------------------------------
# The per-bucket compile contract (satellite: no per-shape recompilation)
# ---------------------------------------------------------------------------


def test_mixed_trace_compiles_once_per_bucket(qwen):
    cfg, _, params = qwen
    eng = ServeEngine(cfg, params, num_slots=2, page_size=8,
                      max_prompt_len=16, max_new_cap=4, clock="virtual")
    rng = np.random.RandomState(0)
    reqs = []
    from repro.serve import Request
    for i, plen in enumerate([3, 5, 8, 9, 12, 16, 4, 11]):   # buckets {8,16}
        reqs.append(Request(
            rid=i, arrival=0.0,
            prompt=rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=3))
    eng.run(reqs)
    assert eng.prefill_compiles == 2      # one per bucket, not per length
    assert eng.decode_compiles == 1
    eng.run(reqs)                         # replay: everything cached
    assert eng.prefill_compiles == 2
    assert eng.decode_compiles == 1


def test_greedy_generate_bucketed_cache(qwen):
    """The toy path satellite: mixed max_len requests share one power-of-
    two cache bucket, and bucketing doesn't change the tokens."""
    cfg, model, params = qwen
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 5), 0,
                                cfg.vocab_size)
    a = greedy_generate(model, params, prompt, 4, 11)
    b = greedy_generate(model, params, prompt, 4, 11, bucket=False)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert bucketed_max_len(11) == 16


# ---------------------------------------------------------------------------
# Int8 paged KV (satellite)
# ---------------------------------------------------------------------------


def test_int8_page_roundtrip_error_bound():
    """Per-(position, head) scales: dequantization error is bounded by half
    a quantization step of the stored (f16) scale, with a hair of slack
    for the scale's own storage rounding."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 2, 8)) * \
        jnp.asarray([0.1, 1.0, 10.0])[:, None, None, None]
    q, scale = _quantize_kv(x)
    assert q.dtype == jnp.int8
    assert scale.dtype == jnp.float16
    deq = _dequantize_kv(q, scale, jnp.float32)
    err = jnp.abs(deq - x)
    step = scale.astype(jnp.float32)[..., None]
    assert bool(jnp.all(err <= 0.52 * step + 1e-8))


def test_int8_token_parity_64_steps(qwen):
    """Greedy decode with the int8 paged pool matches fp token-for-token
    over >= 64 steps (qwen3-0.6b smoke)."""
    cfg, _, params = qwen
    from repro.serve import Request
    rng = np.random.RandomState(5)
    reqs = [Request(rid=0, arrival=0.0,
                    prompt=rng.randint(0, cfg.vocab_size, size=6).astype(
                        np.int32),
                    max_new=64)]
    kw = dict(num_slots=1, page_size=8, max_prompt_len=8, max_new_cap=64,
              clock="virtual")
    fp = ServeEngine(cfg, params, **kw).run(reqs)
    q8 = ServeEngine(cfg, params, cache_int8=True, **kw).run(reqs)
    fp_toks, q8_toks = fp.tokens_by_rid()[0], q8.tokens_by_rid()[0]
    assert len(fp_toks) == 64
    assert fp_toks == q8_toks


# ---------------------------------------------------------------------------
# Scheduling policies
# ---------------------------------------------------------------------------


def test_static_policy_same_tokens_more_steps(qwen, qwen_engine):
    cfg, _, _ = qwen
    trace = _trace(6, rate=100.0, max_new=8, vocab=cfg.vocab_size)
    cont = qwen_engine.run(trace, policy="continuous")
    stat = qwen_engine.run(trace, policy="static")
    assert cont.tokens_by_rid() == stat.tokens_by_rid()
    assert stat.metrics["decode_steps"] >= cont.metrics["decode_steps"]
    # with more requests than slots and mixed lengths, head-of-line
    # blocking costs the static policy strictly more decode steps
    assert stat.metrics["decode_steps"] > cont.metrics["decode_steps"]


def test_request_validation(qwen_engine):
    from repro.serve import Request
    big = Request(rid=0, arrival=0.0,
                  prompt=np.zeros(99, np.int32), max_new=2)
    with pytest.raises(ValueError, match="prompt_len"):
        qwen_engine.run([big])
    greedy = Request(rid=0, arrival=0.0,
                     prompt=np.zeros(4, np.int32), max_new=999)
    with pytest.raises(ValueError, match="max_new"):
        qwen_engine.run([greedy])
    with pytest.raises(ValueError, match="policy"):
        qwen_engine.run(_trace(1), policy="adaptive")


def test_engine_rejects_unknown_knobs(qwen):
    cfg, _, params = qwen
    with pytest.raises(ValueError, match="clock"):
        ServeEngine(cfg, params, clock="lamport")
    with pytest.raises(ValueError, match="fault"):
        ServeEngine(cfg, params, clock="virtual", faults="crash@2")


# ---------------------------------------------------------------------------
# Chaos wiring (satellite): p99 degrades, nothing is lost
# ---------------------------------------------------------------------------


def test_chaos_degrades_p99_but_loses_nothing(qwen, qwen_engine):
    cfg, _, params = qwen
    trace = _trace(6, rate=100.0, vocab=cfg.vocab_size)
    base = qwen_engine.run(trace)
    chaotic = ServeEngine(cfg, params, num_slots=3, page_size=4,
                          max_prompt_len=12, max_new_cap=8, clock="virtual",
                          faults="slowdown@2,preempt@6")
    rep = chaotic.run(trace)
    assert rep.metrics["completed"] == len(trace)          # nothing lost
    assert rep.tokens_by_rid() == base.tokens_by_rid()     # greedy replay
    assert rep.metrics["p99_latency"] > base.metrics["p99_latency"]
    assert rep.metrics["preemptions"] == 1
    assert {e["event"] for e in rep.events} == {"slowdown", "preempt"}
    assert max(c.preemptions for c in rep.completed) >= 1


# ---------------------------------------------------------------------------
# Checkpoint -> serve bridge (satellite)
# ---------------------------------------------------------------------------


def test_train_then_serve_roundtrip(tmp_path):
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    OptimizerConfig, ShapeConfig, TrainConfig)
    from repro.train.loop import Trainer

    cfg = configs.get_smoke_config("qwen3-0.6b")
    tcfg = TrainConfig(
        model=cfg, shape=ShapeConfig("tiny", 16, 8, "train"),
        aggregation=AggregationConfig(strategy="full_sync", num_workers=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.9),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=2),
        log_every=10)
    tr = Trainer(tcfg)
    tr.init_state()
    tr.run(4)
    tr.save_checkpoint()

    params, manifest = restore_params(str(tmp_path), cfg)
    assert manifest["step"] >= 4
    trained = np.asarray(jax.tree_util.tree_leaves(tr.params)[0])
    served = np.asarray(jax.tree_util.tree_leaves(params)[0])
    np.testing.assert_array_equal(trained, served)

    eng = ServeEngine(cfg, params, num_slots=2, page_size=4,
                      max_prompt_len=8, max_new_cap=4, clock="virtual")
    rep = eng.run(_trace(3, max_prompt=8, max_new=4, vocab=cfg.vocab_size))
    assert rep.metrics["completed"] == 3

    ema_params, _ = restore_params(str(tmp_path), cfg, use_ema=True)
    ema_leaf = np.asarray(jax.tree_util.tree_leaves(ema_params)[0])
    assert not np.array_equal(ema_leaf, served)            # ema != raw


def test_restore_missing_checkpoint(tmp_path):
    cfg = configs.get_smoke_config("qwen3-0.6b")
    with pytest.raises(FileNotFoundError):
        restore_params(str(tmp_path / "nope"), cfg)


# ---------------------------------------------------------------------------
# Graceful degradation: overflow/exhaustion reject, never wedge
# ---------------------------------------------------------------------------


def test_strict_capacity_still_raises_by_default(qwen):
    cfg, _, params = qwen
    with pytest.raises(ValueError, match="strict_capacity=False"):
        ServeEngine(cfg, params, num_slots=2, page_size=4,
                    max_prompt_len=12, max_new_cap=8, num_pages=3)


def test_undersized_pool_rejects_long_prompts_structured(qwen):
    """strict_capacity=False permits a pool too small for the longest
    admissible request; those requests are rejected with a structured
    reason while everything that fits still completes."""
    cfg, _, params = qwen
    eng = ServeEngine(cfg, params, num_slots=2, page_size=4,
                      max_prompt_len=16, max_new_cap=8, num_pages=4,
                      strict_capacity=False, clock="virtual")
    assert eng.page_capacity == 3
    short = _trace(3, max_prompt=6, max_new=4)      # needs <= 3 pages
    long = make_trace(TraceConfig(
        num_requests=2, rate=4.0, prompt_len_min=13, prompt_len_max=16,
        max_new_min=4, max_new_max=8, vocab=128, seed=7))
    trace = sorted(short + [type(r)(r.rid + 100, r.arrival, r.prompt,
                                    r.max_new) for r in long],
                   key=lambda r: (r.arrival, r.rid))
    rep = eng.run(trace)
    assert rep.metrics["completed"] == 3
    assert rep.metrics["rejected"] == 2
    assert rep.metrics["rejected_pool_exhausted"] == 2
    assert all(r["reason"] == "pool_exhausted" and r["rid"] >= 100
               for r in rep.rejected)
    assert {c.rid for c in rep.completed} == {r.rid for r in short}


def test_queue_overflow_rejects_structured(qwen):
    cfg, _, params = qwen
    eng = ServeEngine(cfg, params, num_slots=1, page_size=4,
                      max_prompt_len=8, max_new_cap=8, max_queue=2,
                      clock="virtual")
    trace = _trace(8, rate=1000.0, max_prompt=8, min_new=6, max_new=8)
    rep = eng.run(trace)
    over = [r for r in rep.rejected if r["reason"] == "queue_overflow"]
    assert over and rep.metrics["rejected_queue_overflow"] == len(over)
    assert rep.metrics["completed"] + rep.metrics["rejected"] == len(trace)
    assert rep.metrics["completed"] >= 1
    # rejection is part of the deterministic virtual-time replay
    rep2 = eng.run(trace)
    assert rep2.rejected == rep.rejected
