"""SPMD execution engine: mesh parity with the simulated backend.

Mesh semantics run in subprocesses with xla_force_host_platform_device_count
(the main test process keeps 1 device per the dry-run contract — see
tests/conftest.py); the engine's degenerate mesh_data=1 case and the pure
helpers run in process so tier-1 covers the engine on every change.
"""
from pathlib import Path

import numpy as np
import pytest

from test_spmd_subprocess import run_py as _run_py

_ROOT = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    return _run_py(code, devices=devices, timeout=timeout)


# ---------------------------------------------------------------------------
# In-process: pure helpers + the degenerate single-device mesh
# ---------------------------------------------------------------------------


def test_flatten_unflatten_roundtrip():
    import jax
    import jax.numpy as jnp
    from repro.distributed import spmd_engine

    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(2, 3, 2),
            "b": {"w": jnp.ones((2, 5), jnp.float32),
                  "s": jnp.asarray([2.0, 3.0])}}
    flat, spec = spmd_engine.flatten_stacked(tree)
    assert flat.shape == (2, 6 + 5 + 1)
    rec = spmd_engine.unflatten_vector(flat[1], spec)
    for a, b in zip(jax.tree_util.tree_leaves(rec),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[1])


def test_layout_validation_errors():
    from repro.configs.base import ExecutionConfig
    from repro.distributed import spmd_engine

    with pytest.raises(ValueError, match="divisible by"):
        spmd_engine.validate_layout(6, 24, 4)         # 6 workers on 4 shards
    with pytest.raises(ValueError, match="global_batch"):
        spmd_engine.validate_layout(4, 22, 4)
    assert spmd_engine.validate_layout(8, 16, 4) == 2
    # asking for more devices than exist names the XLA_FLAGS escape hatch
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        spmd_engine.build_mesh(ExecutionConfig(backend="spmd", mesh_data=64))


def test_unknown_execution_backend_rejected(tmp_path):
    from repro import configs
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    ExecutionConfig, ShapeConfig, TrainConfig)
    from repro.train.loop import Trainer

    cfg = TrainConfig(model=configs.get_smoke_config("qwen3-0.6b"),
                      shape=ShapeConfig("t", 16, 8, "train"),
                      aggregation=AggregationConfig(strategy="backup",
                                                    num_workers=3,
                                                    backup_workers=1),
                      checkpoint=CheckpointConfig(directory=str(tmp_path)),
                      execution=ExecutionConfig(backend="tpu_pod"))
    with pytest.raises(ValueError, match="unknown execution backend"):
        Trainer(cfg)


def _tiny_model_cfg():
    from repro import configs
    from repro.configs.base import replace
    return replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                   d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                   d_ff=64, vocab_size=64, vocab_pad_multiple=16)


def _train_cfg(backend, tmp_path, *, strategy="backup", workers=6, backups=2,
               deadline=0.5, mesh_data=1, mesh_model=1, chunk=1, every=0,
               use_kernel=True, grad_batch=0, bucket_size=0):
    from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                    ExecutionConfig, OptimizerConfig,
                                    ShapeConfig, TrainConfig)
    total = workers + backups
    return TrainConfig(
        model=_tiny_model_cfg(),
        shape=ShapeConfig("t", 16, 2 * total, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups,
                                      deadline_s=deadline),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False,
                                  ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=every),
        execution=ExecutionConfig(backend=backend, mesh_data=mesh_data,
                                  mesh_model=mesh_model,
                                  use_kernel=use_kernel,
                                  grad_batch=grad_batch,
                                  bucket_size=bucket_size),
        seed=0, total_steps=6, log_every=1, chunk_size=chunk)


def _assert_close_trees(a, b, rtol=2e-4, atol=2e-5):
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("chunk", [1, 3])
def test_spmd_single_device_mesh_matches_sim(tmp_path, chunk):
    """mesh_data=1 runs the full engine (shard_map + kernel reduce + psum)
    on the real single device — in-process tier-1 coverage of the code
    path the multi-device subprocess tests exercise at scale."""
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    lat = Uniform(1.0, 2.0)
    ta = Trainer(_train_cfg("sim", tmp_path / "a", chunk=chunk), latency=lat)
    ta.init_state()
    ra = ta.run(6)
    tb = Trainer(_train_cfg("spmd", tmp_path / "b", chunk=chunk), latency=lat)
    tb.init_state()
    rb = tb.run(6)
    _assert_close_trees(ra.params, rb.params)
    _assert_close_trees(ra.ema, rb.ema)
    np.testing.assert_allclose([m["loss"] for m in ra.metrics],
                               [m["loss"] for m in rb.metrics],
                               rtol=2e-4, atol=2e-5)
    assert ra.sim_time == rb.sim_time
    assert [m["selected"] for m in ra.metrics] == \
        [m["selected"] for m in rb.metrics]


def test_grad_batch_validation_errors():
    """ExecutionConfig.grad_batch: structured errors on bad worker-batch
    sizes — negatives and non-divisors of W_local (listing the valid
    divisors), with 0 resolving to the full-vmap fast path."""
    from repro.distributed.spmd_engine import validate_grad_batch

    assert validate_grad_batch(0, 4) == 4       # vmap ALL local workers
    assert validate_grad_batch(1, 4) == 1       # sequential lax.map
    assert validate_grad_batch(2, 4) == 2       # microbatches of 2
    assert validate_grad_batch(6, 6) == 6
    with pytest.raises(ValueError, match="non-negative"):
        validate_grad_batch(-1, 4)
    with pytest.raises(ValueError, match=r"0 \(vmap all\) or one of "
                                         r"\[1, 2, 3, 6\]"):
        validate_grad_batch(4, 6)
    with pytest.raises(ValueError, match="does not divide"):
        validate_grad_batch(8, 4)


@pytest.mark.parametrize("grad_batch", [1, 2, 4])
def test_spmd_grad_batch_paths_match_vmap(tmp_path, grad_batch):
    """The three per-worker batching strategies (full vmap, sequential
    lax.map, vmapped microbatches) are the SAME function: identical
    trajectories on the single-device mesh, in-process for tier-1.
    W_local = 8 here (6 workers + 2 backups on mesh_data=1), so
    grad_batch=2 and 4 are genuine microbatches."""
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    lat = Uniform(1.0, 2.0)
    tv = Trainer(_train_cfg("spmd", tmp_path / "v", chunk=2, grad_batch=0),
                 latency=lat)
    tv.init_state()
    rv = tv.run(4)
    tb = Trainer(_train_cfg("spmd", tmp_path / "b", chunk=2,
                            grad_batch=grad_batch), latency=lat)
    tb.init_state()
    rb = tb.run(4)
    _assert_close_trees(rv.params, rb.params, rtol=1e-5, atol=1e-6)
    _assert_close_trees(rv.ema, rb.ema, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose([m["loss"] for m in rv.metrics],
                               [m["loss"] for m in rb.metrics],
                               rtol=1e-5, atol=1e-6)


def test_spmd_bucketed_psum_matches_single_bucket(tmp_path):
    """bucket_size > 0 cuts the fused flatten into several collectives
    (the tail scalars riding the last); the trajectory must not move."""
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    lat = Uniform(1.0, 2.0)
    t1 = Trainer(_train_cfg("spmd", tmp_path / "one", chunk=2),
                 latency=lat)
    t1.init_state()
    r1 = t1.run(4)
    t2 = Trainer(_train_cfg("spmd", tmp_path / "many", chunk=2,
                            bucket_size=5000), latency=lat)
    t2.init_state()
    r2 = t2.run(4)
    _assert_close_trees(r1.params, r2.params, rtol=1e-5, atol=1e-6)
    _assert_close_trees(r1.ema, r2.ema, rtol=1e-5, atol=1e-6)


def test_spmd_kernel_and_jnp_reduce_agree(tmp_path):
    """The Pallas backup_reduce in-shard reduction == the jnp reference."""
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    lat = Uniform(1.0, 2.0)
    tk = Trainer(_train_cfg("spmd", tmp_path / "k", chunk=2, use_kernel=True),
                 latency=lat)
    tk.init_state()
    rk = tk.run(4)
    tj = Trainer(_train_cfg("spmd", tmp_path / "j", chunk=2, use_kernel=False),
                 latency=lat)
    tj.init_state()
    rj = tj.run(4)
    _assert_close_trees(rk.params, rj.params, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Subprocess: real multi-device meshes (the acceptance parity matrix)
# ---------------------------------------------------------------------------

# Parity + checkpoint/resume for one mesh, all three mask strategies.
# The mesh run must match the single-device simulated Trainer's loss and
# param trajectory (allclose — the engine sums explicit per-worker
# gradients where the sim backend differentiates one weighted loss), and
# resume from a checkpoint taken mid-run must land on the same state.
# On the (4, 2) mesh the 'model' axis does REAL work: params/opt/EMA are
# sharded and the per-worker gradient is computed tensor-parallel
# (docs/spmd.md) — the same parity bars apply unchanged.
_PARITY_CODE = r"""
import numpy as np, jax
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.distributed.sharding import tp_plan
from repro.train.loop import Trainer

MESH_DATA, MESH_MODEL = __MESH__
model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)
if MESH_MODEL > 1:
    # the tiny config divides: every TP group must actually shard
    plan = tp_plan(model_cfg, MESH_MODEL)
    assert plan.attn and plan.ffn and plan.vocab, plan

def cfg(backend, strategy, ck, workers, backups, every=0, chunk=3):
    return TrainConfig(
        model=model_cfg,
        shape=ShapeConfig("t", 16, 16, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups, deadline_s=0.5),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=ck, every_steps=every),
        execution=ExecutionConfig(backend=backend, mesh_data=MESH_DATA,
                                  mesh_model=MESH_MODEL),
        seed=0, total_steps=8, log_every=1, chunk_size=chunk)

def close(a, b, rtol=2e-4, atol=2e-5):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=atol)

lat = Uniform(1.0, 2.0)
for strategy, workers, backups in (("full_sync", 8, 0), ("backup", 6, 2),
                                   ("timeout", 8, 0)):
    ta = Trainer(cfg("sim", strategy, f"/tmp/spmd_sim_{strategy}", workers,
                     backups), latency=lat)
    ta.init_state(); ra = ta.run(8)
    tb = Trainer(cfg("spmd", strategy, f"/tmp/spmd_mesh_{strategy}", workers,
                     backups), latency=lat)
    tb.init_state(); rb = tb.run(8)
    if MESH_MODEL > 1:
        # state genuinely sharded over 'model' (not just allowed to be)
        spec = tb.params["seg_dense"]["attn"]["wq"]["w"].sharding.spec
        assert "model" in tuple(spec), spec
        spec = tb.opt_state["m"]["embed"]["embedding"].sharding.spec
        assert "model" in tuple(spec), spec
    close(ra.params, rb.params)
    close(ra.ema, rb.ema)
    np.testing.assert_allclose([m["loss"] for m in ra.metrics],
                               [m["loss"] for m in rb.metrics],
                               rtol=2e-4, atol=2e-5)
    assert ra.sim_time == rb.sim_time
    assert [m["selected"] for m in ra.metrics] == \
        [m["selected"] for m in rb.metrics]
    print(strategy, "parity OK")

# checkpoint/resume THROUGH a mesh-executed chunk: every_steps=3 with
# chunk_size=2 puts a forced chunk boundary inside the scan cadence; the
# resumed mesh trainer must rejoin the uninterrupted sim trajectory.
ck = "/tmp/spmd_resume"
t1 = Trainer(cfg("spmd", "backup", ck, 6, 2, every=3, chunk=2), latency=lat)
t1.init_state(); t1.run(3)                       # checkpoints at step 3
t2 = Trainer(cfg("spmd", "backup", ck, 6, 2, every=3, chunk=2), latency=lat)
t2.restore_checkpoint()
assert t2.step == 3
r2 = t2.run(5)                                   # -> step 8
ref = Trainer(cfg("sim", "backup", "/tmp/spmd_resume_ref", 6, 2), latency=lat)
ref.init_state(); rr = ref.run(8)
close(rr.params, r2.params)
close(rr.ema, r2.ema)
assert rr.sim_time == r2.sim_time
print("resume-through-chunk parity OK")
"""


def test_spmd_parity_mesh_4x2():
    out = run_py(_PARITY_CODE.replace("__MESH__", "(4, 2)"))
    assert "resume-through-chunk parity OK" in out


def test_spmd_parity_mesh_8x1():
    out = run_py(_PARITY_CODE.replace("__MESH__", "(8, 1)"))
    assert "resume-through-chunk parity OK" in out


def test_spmd_grad_batch_parity_matrix():
    """The acceptance matrix on a real TP (2, 2) mesh (W_local = 4):
    for every mask strategy, the vmapped (grad_batch=0), sequential
    (grad_batch=1) and microbatched (grad_batch=2, with a multi-bucket
    fused psum) engines all match the single-device sim trajectory —
    batching and bucketing are execution detail, never semantics."""
    run_py(r"""
import numpy as np, jax
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)

def cfg(backend, strategy, ck, workers, backups, grad_batch=0, bucket=0):
    return TrainConfig(
        model=model_cfg, shape=ShapeConfig("t", 16, 16, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups, deadline_s=0.5),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=ck, every_steps=0),
        execution=ExecutionConfig(backend=backend, mesh_data=2, mesh_model=2,
                                  grad_batch=grad_batch, bucket_size=bucket),
        seed=0, total_steps=6, log_every=1, chunk_size=3)

def close(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-5)

lat = Uniform(1.0, 2.0)
for strategy, workers, backups in (("full_sync", 8, 0), ("backup", 6, 2),
                                   ("timeout", 8, 0)):
    ref = Trainer(cfg("sim", strategy, f"/tmp/gbm_sim_{strategy}", workers,
                      backups), latency=lat)
    ref.init_state(); rr = ref.run(6)
    for gb, bucket in ((0, 0), (1, 0), (2, 5000)):
        tr = Trainer(cfg("spmd", strategy, f"/tmp/gbm_{strategy}_{gb}",
                         workers, backups, gb, bucket), latency=lat)
        tr.init_state(); rt = tr.run(6)
        close(rr.params, rt.params)
        close(rr.ema, rt.ema)
        np.testing.assert_allclose([m["loss"] for m in rr.metrics],
                                   [m["loss"] for m in rt.metrics],
                                   rtol=2e-4, atol=2e-5)
        assert rr.sim_time == rt.sim_time
        assert [m["selected"] for m in rr.metrics] == \
            [m["selected"] for m in rt.metrics]
        print(strategy, "gb", gb, "bucket", bucket, "parity OK")
print("grad-batch matrix OK")
""")


def test_spmd_grad_batch_resume_through_chunk_tp():
    """Checkpoint/resume THROUGH a mesh chunk with grad_batch=2 on the
    TP (4, 2) mesh: the batched-gradient engine rejoins the
    uninterrupted sim trajectory exactly like the default engine."""
    run_py(r"""
import numpy as np, jax
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)

def cfg(backend, ck, mesh=(1, 1), grad_batch=0, every=0):
    return TrainConfig(
        model=model_cfg, shape=ShapeConfig("t", 16, 16, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=6,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=ck, every_steps=every),
        execution=ExecutionConfig(backend=backend, mesh_data=mesh[0],
                                  mesh_model=mesh[1], grad_batch=grad_batch),
        seed=0, total_steps=8, log_every=1, chunk_size=2)

def close(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-5)

lat = Uniform(1.0, 2.0)
ck = "/tmp/gb_resume"
t1 = Trainer(cfg("spmd", ck, (4, 2), grad_batch=2, every=3), latency=lat)
t1.init_state(); t1.run(3)                       # checkpoints at step 3
t2 = Trainer(cfg("spmd", ck, (4, 2), grad_batch=2, every=3), latency=lat)
t2.restore_checkpoint()
assert t2.step == 3
r2 = t2.run(5)                                   # -> step 8
ref = Trainer(cfg("sim", "/tmp/gb_resume_ref"), latency=lat)
ref.init_state(); rr = ref.run(8)
close(rr.params, r2.params)
close(rr.ema, r2.ema)
assert rr.sim_time == r2.sim_time
print("grad-batch resume-through-chunk parity OK")
""")


def test_spmd_rescale_shrinks_worker_axis():
    """When failures push alive below N, the elastic rescale shrinks the
    mesh 'data' axis to the largest size the new worker count divides —
    the run continues instead of crashing in layout validation."""
    run_py(r"""
import numpy as np
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)
cfg = TrainConfig(
    model=model_cfg,
    shape=ShapeConfig("t", 16, 16, "train"),
    aggregation=AggregationConfig(strategy="full_sync", num_workers=8),
    optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                              scale_lr_with_workers=False, ema_decay=0.0),
    checkpoint=CheckpointConfig(directory="/tmp/spmd_rescale", every_steps=0),
    execution=ExecutionConfig(backend="spmd", mesh_data=8),
    seed=0, total_steps=6, log_every=1, chunk_size=2)
tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
tr.init_state()
res = tr.run(6, kill_worker_at={2: 3})
assert res.restarts == 1
# 7 alive -> rounded to 4 (divisor of batch 16); mesh axis follows
assert tr.cfg.aggregation.total_workers == 4
assert tr.cfg.execution.mesh_data == 4
assert res.steps == 6
assert all(np.isfinite([m["loss"] for m in res.metrics]))
print("spmd rescale OK")
""")


def test_spmd_cli_smoke():
    """--execution spmd --mesh-data N end to end through the launcher."""
    run_py(r"""
from repro.launch import train as train_cli
train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "4",
                "--workers", "3", "--backups", "1", "--batch-per-worker", "2",
                "--seq", "16", "--ckpt", "/tmp/spmd_cli_ck",
                "--optimizer", "momentum", "--lr", "0.05",
                "--execution", "spmd", "--mesh-data", "4",
                "--chunk-size", "2"])
import os
assert os.path.exists(os.path.join("/tmp/spmd_cli_ck", "LATEST"))
print("spmd cli OK")
""", devices=4)


@pytest.mark.parametrize("argv", [
    ["--strategy", "backup", "--mesh-data", "2"],              # no spmd
    ["--strategy", "backup", "--mesh-model", "2"],             # no spmd
    ["--strategy", "async", "--execution", "spmd"],            # event regime
    ["--strategy", "backup", "--execution", "spmd",
     "--straggler-backend", "device"],                         # device masks
    ["--strategy", "backup", "--workers", "3", "--backups", "0",
     "--execution", "spmd", "--mesh-data", "2"],               # 3 % 2 != 0
    ["--strategy", "backup", "--grad-batch", "2"],             # no spmd
    ["--strategy", "backup", "--bucket-size", "4096"],         # no spmd
    ["--strategy", "backup", "--workers", "6", "--backups", "2",
     "--execution", "spmd", "--mesh-data", "2",
     "--grad-batch", "3"],                                     # 4 % 3 != 0
    ["--strategy", "backup", "--execution", "spmd",
     "--grad-batch", "-1"],                                    # negative
])
def test_spmd_cli_rejects_mismatched_args(argv):
    from repro.launch import train as train_cli
    with pytest.raises(SystemExit):
        train_cli.main(argv + ["--smoke", "--steps", "1"])


def test_grad_batch_cli_error_names_valid_divisors(capsys):
    """The argparse error surfaces the engine's structured message: the
    offending value AND the divisors that would work."""
    from repro.launch import train as train_cli
    with pytest.raises(SystemExit):
        train_cli.main(["--strategy", "backup", "--workers", "6",
                        "--backups", "2", "--execution", "spmd",
                        "--mesh-data", "2", "--grad-batch", "3",
                        "--smoke", "--steps", "1"])
    err = capsys.readouterr().err
    assert "--grad-batch: grad_batch: 3 does not divide" in err
    assert "W_local=4" in err
    assert "[1, 2, 4]" in err


def test_spmd_grad_batch_cli_smoke():
    """--grad-batch / --bucket-size thread from argv to the engine."""
    run_py(r"""
from repro.launch import train as train_cli
train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "4",
                "--workers", "3", "--backups", "1", "--batch-per-worker", "2",
                "--seq", "16", "--ckpt", "/tmp/gb_cli_ck",
                "--optimizer", "momentum", "--lr", "0.05",
                "--execution", "spmd", "--mesh-data", "2",
                "--grad-batch", "2", "--bucket-size", "4096",
                "--chunk-size", "2"])
import os
assert os.path.exists(os.path.join("/tmp/gb_cli_ck", "LATEST"))
print("grad-batch cli OK")
""", devices=4)


def test_spmd_regression_guard(tmp_path):
    """check_spmd_regression: ratios guard against DROPS, the bytes axis
    against GROWTH, small drift passes, >20% fails with exit 1."""
    import importlib
    import json
    import sys as _sys

    _sys.path.insert(0, str(_ROOT / "benchmarks"))
    guard = importlib.import_module("check_spmd_regression")

    base = {"bench": "spmd",
            "spmd_vs_sim_w8_chunk32_m1": 0.50,
            "spmd_bytes_per_step_w8_chunk32_m1": 50000.0}

    def check(fresh):
        b, f = tmp_path / "base.json", tmp_path / "fresh.json"
        b.write_text(json.dumps(base))
        f.write_text(json.dumps({"bench": "spmd", **fresh}))
        return guard.main([str(b), str(f)])

    assert check({"spmd_vs_sim_w8_chunk32_m1": 0.45,          # -10%: ok
                  "spmd_bytes_per_step_w8_chunk32_m1": 55000.0}) == 0
    assert check({"spmd_vs_sim_w8_chunk32_m1": 0.65,          # improvement
                  "spmd_bytes_per_step_w8_chunk32_m1": 30000.0}) == 0
    assert check({"spmd_vs_sim_w8_chunk32_m1": 0.39,          # -22%: fail
                  "spmd_bytes_per_step_w8_chunk32_m1": 50000.0}) == 1
    assert check({"spmd_vs_sim_w8_chunk32_m1": 0.50,
                  "spmd_bytes_per_step_w8_chunk32_m1": 65000.0}) == 1  # +30%
    # new cells in fresh / cells only in baseline never fail the guard
    assert check({"spmd_vs_sim_w8_chunk32_m1": 0.50,
                  "spmd_vs_sim_w16_chunk64_m4": 0.9}) == 0


def test_bench_run_forwards_flags(monkeypatch, tmp_path):
    """bench_spmd.run() re-execs itself in a fresh subprocess (the forced
    device count must precede jax init); trace/metrics/platform requests
    must survive that hop — forwarded from env to the child's argv."""
    import importlib
    import os as _os
    import subprocess as _sp
    import sys as _sys

    saved = _os.environ.get("XLA_FLAGS")
    _sys.path.insert(0, str(_ROOT / "benchmarks"))
    try:
        bench_spmd = importlib.import_module("bench_spmd")
    finally:
        if saved is None:
            _os.environ.pop("XLA_FLAGS", None)
        else:
            _os.environ["XLA_FLAGS"] = saved
    calls = []

    def fake_run(cmd, **kw):
        calls.append(cmd)
        return _sp.CompletedProcess(cmd, 0)

    monkeypatch.setattr(bench_spmd.subprocess, "run", fake_run)
    monkeypatch.setenv("REPRO_BENCH_TRACE", str(tmp_path / "t.json"))
    monkeypatch.setenv("REPRO_BENCH_METRICS", str(tmp_path / "m.jsonl"))
    monkeypatch.setenv("REPRO_BENCH_PLATFORM", "cpu")
    rows = bench_spmd.run(quick=True)
    (cmd,) = calls
    assert "--quick" in cmd
    assert cmd[cmd.index("--trace") + 1] == str(tmp_path / "t.json")
    assert cmd[cmd.index("--metrics") + 1] == str(tmp_path / "m.jsonl")
    assert cmd[cmd.index("--platform") + 1] == "cpu"
    # rows come from the committed BENCH payload (the child was faked)
    assert any(name.startswith("spmd.spmd_vs_sim") for name, _, _ in rows)


# ---------------------------------------------------------------------------
# Tensor parallelism over the 'model' axis (subprocess — needs >= 8 devices)
# ---------------------------------------------------------------------------


def test_spmd_tp_triple_parity_and_checkpoint_interchange():
    """The acceptance triangle: the (4,2) TENSOR-PARALLEL run, the (8,1)
    replicated mesh run, and the single-device sim agree (allclose params/
    EMA/losses, identical masks/sim_time) — and a checkpoint written by
    the sharded run resumes in all three (gather happens only at the
    save/restore boundary, so the on-disk format is one format)."""
    run_py(r"""
import numpy as np, jax, shutil
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)

def cfg(backend, ck, mesh=(1, 1), chunk=2, every=3):
    return TrainConfig(
        model=model_cfg, shape=ShapeConfig("t", 16, 16, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=6,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=ck, every_steps=every),
        execution=ExecutionConfig(backend=backend, mesh_data=mesh[0],
                                  mesh_model=mesh[1]),
        seed=0, total_steps=8, log_every=1, chunk_size=chunk)

lat = Uniform(1.0, 2.0)
def close(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-5)

# -- triple parity, full 8 steps -------------------------------------------
runs = {}
for name, backend, mesh in (("sim", "sim", (1, 1)),
                            ("rep", "spmd", (8, 1)),
                            ("tp", "spmd", (4, 2))):
    tr = Trainer(cfg(backend, f"/tmp/tp3_{name}", mesh, every=0), latency=lat)
    tr.init_state()
    runs[name] = (tr, tr.run(8))
(_, rs), (_, rr), (ttp, rt) = runs["sim"], runs["rep"], runs["tp"]
assert "model" in tuple(
    ttp.params["seg_dense"]["mlp"]["w_down"]["w"].sharding.spec)
assert "model" in tuple(ttp.ema["embed"]["embedding"].sharding.spec)
for a, b in ((rs, rr), (rs, rt), (rr, rt)):
    close(a.params, b.params); close(a.ema, b.ema)
    np.testing.assert_allclose([m["loss"] for m in a.metrics],
                               [m["loss"] for m in b.metrics],
                               rtol=2e-4, atol=2e-5)
    assert a.sim_time == b.sim_time
    assert [m["selected"] for m in a.metrics] == \
        [m["selected"] for m in b.metrics]
print("triple parity OK")

# -- sharded checkpoint -> each of the three backends ----------------------
shutil.rmtree("/tmp/tp3_ck", ignore_errors=True)
t1 = Trainer(cfg("spmd", "/tmp/tp3_ck", (4, 2)), latency=lat)
t1.init_state(); t1.run(3)                     # checkpoints (sharded) at 3
for name, backend, mesh in (("tp", "spmd", (4, 2)),
                            ("rep", "spmd", (8, 1)),
                            ("sim", "sim", (1, 1))):
    d = f"/tmp/tp3_resume_{name}"
    shutil.rmtree(d, ignore_errors=True); shutil.copytree("/tmp/tp3_ck", d)
    t2 = Trainer(cfg(backend, d, mesh), latency=lat)
    t2.restore_checkpoint()
    assert t2.step == 3
    r2 = t2.run(5)                             # resume THROUGH sharded chunks
    close(rs.params, r2.params); close(rs.ema, r2.ema)
    assert rs.sim_time == r2.sim_time
    print(f"resume into {name} OK")
print("sharded checkpoint interchange OK")
""")


def test_spmd_tp_kernel_and_jnp_reduce_agree():
    """The Pallas backup_reduce over each shard's LOCAL [W_local, P_local]
    flatten == the jnp reference reduction, on a tensor-parallel mesh."""
    run_py(r"""
import numpy as np, jax
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)

def cfg(ck, use_kernel):
    return TrainConfig(
        model=model_cfg, shape=ShapeConfig("t", 16, 16, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=6,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.99),
        checkpoint=CheckpointConfig(directory=ck, every_steps=0),
        execution=ExecutionConfig(backend="spmd", mesh_data=4, mesh_model=2,
                                  use_kernel=use_kernel),
        seed=0, total_steps=4, log_every=1, chunk_size=2)

lat = Uniform(1.0, 2.0)
tk = Trainer(cfg("/tmp/tpk_k", True), latency=lat); tk.init_state()
rk = tk.run(4)
tj = Trainer(cfg("/tmp/tpk_j", False), latency=lat); tj.init_state()
rj = tj.run(4)
for x, y in zip(jax.tree_util.tree_leaves(rk.params),
                jax.tree_util.tree_leaves(rj.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=1e-5, atol=1e-6)
print("tp kernel == jnp reduce OK")
""")


def test_spmd_tp_unshardable_model_falls_back_replicated():
    """mesh_model=2 with an indivisible config: the engine warns, carries
    the 'model' axis replicated (pre-TP semantics), and parity with the
    sim backend still holds."""
    run_py(r"""
import warnings
import numpy as np, jax
from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core.straggler import Uniform
from repro.train.loop import Trainer

# 3 heads / 3 kv heads, odd d_ff, odd padded vocab: nothing divides by 2
model_cfg = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=3, num_kv_heads=3, head_dim=8,
                    d_ff=65, vocab_size=63, vocab_pad_multiple=9)

def cfg(backend, ck, mesh=(1, 1)):
    return TrainConfig(
        model=model_cfg, shape=ShapeConfig("t", 16, 16, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=6,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.0),
        checkpoint=CheckpointConfig(directory=ck, every_steps=0),
        execution=ExecutionConfig(backend=backend, mesh_data=mesh[0],
                                  mesh_model=mesh[1]),
        seed=0, total_steps=4, log_every=1, chunk_size=1)

lat = Uniform(1.0, 2.0)
ta = Trainer(cfg("sim", "/tmp/tpf_sim"), latency=lat); ta.init_state()
ra = ta.run(4)
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    tb = Trainer(cfg("spmd", "/tmp/tpf_mesh", (4, 2)), latency=lat)
assert any("carried (replicated)" in str(x.message) for x in w), \
    [str(x.message) for x in w]
tb.init_state()
rb = tb.run(4)
# replicated over the whole mesh: no 'model' entry in any param spec
spec = tb.params["seg_dense"]["attn"]["wq"]["w"].sharding.spec
assert "model" not in tuple(spec), spec
for x, y in zip(jax.tree_util.tree_leaves(ra.params),
                jax.tree_util.tree_leaves(rb.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=2e-4, atol=2e-5)
print("unshardable fallback OK")
""")


def test_spmd_tp_cli_smoke():
    """--execution spmd --mesh-data 4 --mesh-model 2 through the launcher."""
    run_py(r"""
from repro.launch import train as train_cli
train_cli.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "4",
                "--workers", "3", "--backups", "1", "--batch-per-worker", "2",
                "--seq", "16", "--ckpt", "/tmp/tp_cli_ck",
                "--optimizer", "momentum", "--lr", "0.05",
                "--execution", "spmd", "--mesh-data", "4", "--mesh-model", "2",
                "--chunk-size", "2"])
import os
assert os.path.exists(os.path.join("/tmp/tp_cli_ck", "LATEST"))
print("tp cli OK")
""")
