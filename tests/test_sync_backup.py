"""The paper's core identity: masked-weighted loss == Alg. 4 aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core import sync_backup


def _toy(num_workers=8, per=4, dim=16, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    params = {"w": jax.random.normal(k1, (dim,))}
    x = jax.random.normal(k2, (num_workers * per, dim))
    y = jax.random.normal(k3, (num_workers * per,))
    return params, x, y


def _per_example_loss(p, x, y):
    return (x @ p["w"] - y) ** 2


@given(mask_bits=st.lists(st.booleans(), min_size=4, max_size=4))
@settings(max_examples=16, deadline=None)
def test_weighted_loss_equals_explicit_aggregation(mask_bits):
    """For ANY mask, grad of weighted loss == (1/N) sum masked worker grads."""
    w = 4
    n_agg = max(1, sum(mask_bits))
    params, x, y = _toy(num_workers=w)
    mask = jnp.asarray(mask_bits)

    g_weighted = jax.grad(lambda p: sync_backup.weighted_loss(
        _per_example_loss(p, x, y), mask, n_agg))(params)

    def worker_mean(p, batch):
        return jnp.mean(_per_example_loss(p, batch["x"], batch["y"]))

    stacked = sync_backup.per_worker_grads(worker_mean, params,
                                           {"x": x, "y": y}, w)
    g_explicit = sync_backup.aggregate_masked(stacked, mask, n_agg)
    np.testing.assert_allclose(g_weighted["w"], g_explicit["w"],
                               rtol=1e-5, atol=1e-6)


def test_full_mask_equals_plain_mean():
    """b=0 (all selected) recovers ordinary synchronous data parallelism."""
    params, x, y = _toy()
    mask = jnp.ones(8, bool)
    gm = jax.grad(lambda p: sync_backup.weighted_loss(
        _per_example_loss(p, x, y), mask, 8))(params)
    gp = jax.grad(lambda p: jnp.mean(_per_example_loss(p, x, y)))(params)
    np.testing.assert_allclose(gm["w"], gp["w"], rtol=1e-5, atol=1e-6)


def test_dropped_worker_has_zero_influence():
    """Changing a DROPPED worker's data must not change the update."""
    params, x, y = _toy()
    mask = jnp.asarray([True] * 6 + [False] * 2)
    g1 = jax.grad(lambda p: sync_backup.weighted_loss(
        _per_example_loss(p, x, y), mask, 6))(params)
    x2 = x.at[-8:].set(100.0)         # corrupt workers 6,7 (dropped)
    g2 = jax.grad(lambda p: sync_backup.weighted_loss(
        _per_example_loss(p, x2, y), mask, 6))(params)
    np.testing.assert_allclose(g1["w"], g2["w"], rtol=1e-6)


def test_per_example_weights_sum():
    """Weights sum to (#selected / N): == 1 exactly when N workers survive."""
    mask = jnp.asarray([1, 1, 0, 1], bool)
    w = sync_backup.per_example_weights(mask, 16, 3)
    assert w.shape == (16,)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


@given(n=st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_make_mask_selects_fastest_n(n):
    rank = jnp.asarray(np.random.RandomState(0).permutation(8))
    mask = sync_backup.make_mask(rank, n)
    assert int(mask.sum()) == n
    # every selected worker is faster than every dropped worker
    sel = np.asarray(rank)[np.asarray(mask)]
    drop = np.asarray(rank)[~np.asarray(mask)]
    assert len(sel) == 0 or len(drop) == 0 or sel.max() < drop.min()


def test_worker_of_example_contiguous():
    w = sync_backup.worker_of_example(12, 3)
    np.testing.assert_array_equal(w, [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2])
