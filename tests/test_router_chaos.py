"""Router failover under replica-scope chaos: crash/restart drains with
zero lost requests and token parity, preemption auto-revives, total
outage degrades to structured rejection, random replica placement is
seeded, and whole chaos runs (health log included) replay bit-identically
— the serving twin of test_chaos.py's training-side guarantees."""
import jax
import pytest

from repro import configs
from repro.models import get_model
from repro.serve import (ReplicaRouter, RouterConfig, SLOConfig, ServeEngine,
                         TraceConfig, make_trace)


def _trace(n=24, *, seed=0, rate=2.0):
    return make_trace(TraceConfig(
        num_requests=n, rate=rate, prompt_len_min=2, prompt_len_max=12,
        max_new_min=4, max_new_max=8, vocab=128, seed=seed))


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, num_slots=2, page_size=4,
                       max_prompt_len=12, max_new_cap=8, clock="virtual")


def _accounted(report, trace):
    done = {c.rid for c in report.completed}
    rej = {r["rid"] for r in report.rejected}
    assert not done & rej
    assert done | rej == {r.rid for r in trace}
    assert report.metrics["lost_requests"] == 0


def test_crash_restart_drains_and_recovers(engine):
    trace = _trace()
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=3, faults="crash@4:r1,restart@20:r1")).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == len(trace), "zero lost requests"
    assert rep.metrics["crashes"] == 1 and rep.metrics["restarts"] == 1
    assert rep.metrics["preempts"] == 0
    assert rep.metrics["drained"] > 0
    # drained requests recompute from scratch: token-identical (greedy)
    assert rep.tokens_by_rid() == engine.run(trace).tokens_by_rid()
    kinds = [e["event"] for e in rep.health]
    assert "down" in kinds and "up" in kinds
    assert any(c.drains > 0 for c in rep.completed)


def test_drained_requests_redispatch_in_arrival_order(engine):
    trace = _trace()
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=2, faults="crash@6:r0,restart@40:r0")).run(trace)
    _accounted(rep, trace)
    drained = sorted((c for c in rep.completed if c.drains > 0),
                     key=lambda c: c.admitted)
    assert [c.rid for c in drained] == \
        [c.rid for c in sorted(drained, key=lambda c: (c.arrival, c.rid))]


def test_preempt_auto_revives(engine):
    trace = _trace()
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=2, faults="preempt@3:r0:d10")).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == len(trace)
    assert rep.metrics["restarts"] == 1, "preemption returns by itself"
    assert rep.metrics["preempts"] == 1 and rep.metrics["crashes"] == 0, \
        "preemptions must not be conflated with crashes"
    assert rep.tokens_by_rid() == engine.run(trace).tokens_by_rid()


def test_total_outage_rejects_structured_not_lost(engine):
    trace = _trace(12)
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=2, faults="crash@2:r0,crash@2:r1")).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["rejected"] > 0
    assert all(r["reason"] == "no_healthy_replica" for r in rep.rejected)


def test_hedging_survives_hedge_replica_crash(engine):
    # the straggling primary is slow, the hedge target then crashes: the
    # surviving copy must be promoted and no request lost
    trace = _trace()
    rep = ReplicaRouter(engine, RouterConfig(
        num_replicas=3, hedge_after=4.0,
        faults="slowdown@0:r0:x10:d400,crash@12:r1,restart@60:r1")
    ).run(trace)
    _accounted(rep, trace)
    assert rep.metrics["completed"] == len(trace)
    assert rep.tokens_by_rid() == engine.run(trace).tokens_by_rid()


def test_random_replica_placement_is_seeded(engine):
    trace = _trace(12)
    mk = lambda s: ReplicaRouter(engine, RouterConfig(  # noqa: E731
        num_replicas=3, faults="crash=2,restart@80:r0,restart@80:r1,"
        "restart@80:r2", fault_seed=s, fault_horizon=12)).run(trace)
    a, b = mk(7), mk(7)
    assert a.health == b.health
    assert a.metrics == b.metrics
    assert mk(8).health != a.health or mk(8).metrics != a.metrics


def test_chaos_replay_bit_identical(engine):
    trace = _trace()
    mk = lambda: ReplicaRouter(engine, RouterConfig(  # noqa: E731
        num_replicas=3, hedge_after=5.0, timeout=60.0,
        faults="slowdown@0:r0:x8:d50,crash@10:r2,restart@30:r2,"
        "preempt@40:r1:d8"), slo=SLOConfig(
            target_p99=40.0, window=16, min_samples=4)).run(trace)
    a, b = mk(), mk()
    _accounted(a, trace)
    assert a.metrics == b.metrics
    assert a.events == b.events
    assert a.health == b.health
    assert a.rejected == b.rejected
    assert a.tokens_by_rid() == b.tokens_by_rid()
