"""Import shim: real hypothesis when installed, else skipping stand-ins.

Modules that mix property tests with deterministic tests import
``given/settings/st`` from here instead of hard-importing hypothesis —
without the package (see requirements-dev.txt) the property tests report
as skipped while everything else in the module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy-construction expression at module scope."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed")
            skipped.__name__ = getattr(fn, "__name__", "hypothesis_test")
            return skipped
        return deco
