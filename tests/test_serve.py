"""Decode-vs-forward equivalence: stepping decode_step token by token must
reproduce the training forward's logits — the strongest KV-cache/ring-
buffer/MLA-cache/recurrent-state correctness check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model

# gemma3 smoke exercises sliding-window ring buffers + global interleave;
# deepseek exercises the MLA latent cache + MoE decode; hymba the parallel
# SSM state; rwkv6 the O(1) recurrence; whisper the self+cross caches.
DECODE_ARCHS = ["qwen3-0.6b", "gemma3-1b", "deepseek-v2-lite-16b",
                "hymba-1.5b", "rwkv6-1.6b", "whisper-tiny",
                "qwen2-moe-a2.7b", "minitron-4b", "command-r-plus-104b",
                "internvl2-2b"]


def _decode_all(model, cfg, params, tokens, max_len, frames=None):
    cache = model.init_cache(tokens.shape[0], max_len)
    if frames is not None:
        cache = model.prime_cross_cache(params, cache, frames)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = step(params, tokens[:, i:i + 1], cache)
        outs.append(logits)
    return jnp.stack(outs, axis=1)          # [B, S, V]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get_smoke_config(arch)
    if arch == "internvl2-2b":
        pytest.skip("vlm decode starts from a primed prefix cache; the "
                    "backbone equals qwen-style GQA covered elsewhere")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    frames = None
    kwargs = {}
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                         (b, cfg.encoder_seq_len, cfg.d_model))
        kwargs["encoder_frames"] = frames
    full = model.forward(params, tokens, **kwargs)            # [B, S, V]
    stepped = _decode_all(model, cfg, params, tokens, max_len=s,
                          frames=frames)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_gemma_ring_buffer_beyond_window():
    """Decode past the sliding window: ring-buffer cache must agree with the
    full forward (local layers only see the last `window` tokens)."""
    cfg = configs.get_smoke_config("gemma3-1b")      # window 8, global every 3
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 20                                      # 2.5x the window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full = model.forward(params, tokens)
    stepped = _decode_all(model, cfg, params, tokens, max_len=s)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_prefill_matches_forward_last_position():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size)
    full = model.forward(params, tokens)
    pre = model.prefill(params, tokens)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_greedy_generate():
    from repro.train.serve_step import greedy_generate
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                cfg.vocab_size)
    out = greedy_generate(model, params, prompt, num_tokens=5, max_len=16)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.padded_vocab).all()


def test_mla_chunked_long_path_matches_dense():
    """The folded (nope‖rope) chunked MLA path == the dense MLA formula."""
    from repro.models import attention
    from repro.configs.base import MLAConfig
    import jax, jax.numpy as jnp
    cfg = configs.get_smoke_config("deepseek-v2-lite-16b")
    params = attention.mla_init(jax.random.PRNGKey(0), cfg)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    dense = attention.mla_attend(params, cfg, x, pos)
    # force the chunked path by lowering the threshold via direct call
    b, s, _ = x.shape
    m = cfg.mla
    h = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = attention._mla_qkv(params, cfg, x, pos)
    k_nope, v = attention._mla_expand_kv(params, cfg, c_kv)
    qk = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_dim))], -1)
    d_qk = m.qk_nope_dim + m.qk_rope_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, d_qk - m.v_head_dim)))
    out = attention.chunked_attention_core(qk, kk, v_pad, causal=True,
                                           q_chunk=8, kv_chunk=8)
    from repro.models import common as mcommon
    chunked = mcommon.dense(params["wo"],
                            out[..., :m.v_head_dim].reshape(b, s, -1))
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-3, atol=2e-3)
