"""Strategy semantics: FullSync / BackupWorkers / Timeout selection rules.

Hypothesis property tests live in test_aggregation_properties.py (skipped
when ``hypothesis`` is absent — see requirements-dev.txt); the deterministic
fallbacks here always run.
"""
import numpy as np
import pytest

from repro.core import aggregation
from repro.configs.base import AggregationConfig


def test_backup_selects_fastest_n_deterministic():
    """Non-hypothesis fallback for BackupWorkers.select (always runs)."""
    rng = np.random.RandomState(7)
    for trial in range(20):
        w = int(rng.randint(5, 33))
        arr = rng.uniform(0.01, 500.0, size=w)
        n = max(1, w - 2)
        s = aggregation.BackupWorkers(n, w - n)
        mask, t = s.select(arr)
        assert mask.sum() == n
        assert t == pytest.approx(np.sort(arr)[n - 1])
        assert set(np.where(mask)[0]) == set(np.argsort(arr, kind="stable")[:n])


def test_fullsync_waits_for_max_deterministic():
    arr = np.array([1.5, 0.3, 7.2, 2.2, 0.9])
    s = aggregation.FullSync(len(arr))
    mask, t = s.select(arr)
    assert mask.all()
    assert t == pytest.approx(7.2)


def test_timeout_always_selects_at_least_one_deterministic():
    arr = np.array([5.0, 1.0, 9.0, 1.4])
    s = aggregation.Timeout(len(arr), 0.5)
    mask, t = s.select(arr)
    assert mask.sum() >= 1
    assert mask[np.argmin(arr)]
    assert t <= arr.min() + 0.5 + 1e-9
    assert list(np.where(mask)[0]) == [1, 3]


def test_backup_faster_than_fullsync():
    """The point of the paper: dropping b stragglers cuts iteration time."""
    rng = np.random.RandomState(0)
    arr = rng.exponential(1.0, size=(1000, 100)) + 1.0
    arr[:, 0] *= 50                      # a consistent straggler
    full = aggregation.FullSync(100)
    backup = aggregation.BackupWorkers(96, 4)
    t_full = np.mean([full.select(a)[1] for a in arr])
    t_backup = np.mean([backup.select(a)[1] for a in arr])
    assert t_backup < t_full * 0.6


def test_from_config():
    s = aggregation.from_config(AggregationConfig(strategy="backup",
                                                  num_workers=6,
                                                  backup_workers=2))
    assert isinstance(s, aggregation.BackupWorkers)
    assert s.total_workers == 8
    s = aggregation.from_config(AggregationConfig(strategy="full_sync",
                                                  num_workers=4))
    assert isinstance(s, aggregation.FullSync)
    s = aggregation.from_config(AggregationConfig(strategy="timeout",
                                                  num_workers=4,
                                                  deadline_s=1.0))
    assert isinstance(s, aggregation.Timeout)
    with pytest.raises(ValueError):
        aggregation.from_config(AggregationConfig(strategy="async"))
