"""Strategy semantics: FullSync / BackupWorkers / Timeout selection rules."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import aggregation
from repro.configs.base import AggregationConfig


arrivals_strategy = st.lists(
    st.floats(min_value=0.01, max_value=500.0, allow_nan=False),
    min_size=5, max_size=32).map(np.array)


@given(arr=arrivals_strategy)
@settings(max_examples=30, deadline=None)
def test_backup_selects_fastest_n(arr):
    n = max(1, len(arr) - 2)
    s = aggregation.BackupWorkers(n, len(arr) - n)
    mask, t = s.select(arr)
    assert mask.sum() == n
    assert t == pytest.approx(np.sort(arr)[n - 1])
    # invariance: selected set == argsort prefix
    assert set(np.where(mask)[0]) == set(np.argsort(arr, kind="stable")[:n])


@given(arr=arrivals_strategy)
@settings(max_examples=30, deadline=None)
def test_fullsync_waits_for_max(arr):
    s = aggregation.FullSync(len(arr))
    mask, t = s.select(arr)
    assert mask.all()
    assert t == pytest.approx(arr.max())


@given(arr=arrivals_strategy, d=st.floats(0.0, 10.0))
@settings(max_examples=30, deadline=None)
def test_timeout_always_selects_at_least_one(arr, d):
    s = aggregation.Timeout(len(arr), d)
    mask, t = s.select(arr)
    assert mask.sum() >= 1
    assert mask[np.argmin(arr)]
    assert t <= arr.min() + d + 1e-9


def test_backup_faster_than_fullsync():
    """The point of the paper: dropping b stragglers cuts iteration time."""
    rng = np.random.RandomState(0)
    arr = rng.exponential(1.0, size=(1000, 100)) + 1.0
    arr[:, 0] *= 50                      # a consistent straggler
    full = aggregation.FullSync(100)
    backup = aggregation.BackupWorkers(96, 4)
    t_full = np.mean([full.select(a)[1] for a in arr])
    t_backup = np.mean([backup.select(a)[1] for a in arr])
    assert t_backup < t_full * 0.6


def test_from_config():
    s = aggregation.from_config(AggregationConfig(strategy="backup",
                                                  num_workers=6,
                                                  backup_workers=2))
    assert isinstance(s, aggregation.BackupWorkers)
    assert s.total_workers == 8
    s = aggregation.from_config(AggregationConfig(strategy="full_sync",
                                                  num_workers=4))
    assert isinstance(s, aggregation.FullSync)
    s = aggregation.from_config(AggregationConfig(strategy="timeout",
                                                  num_workers=4,
                                                  deadline_s=1.0))
    assert isinstance(s, aggregation.Timeout)
    with pytest.raises(ValueError):
        aggregation.from_config(AggregationConfig(strategy="async"))
