"""int8-quantized KV cache: decode must track the bf16-cache decode within
quantization noise (the §Perf memory-term lever for decode shapes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention, get_model


def test_quantize_roundtrip_error():
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 32))
    q, s = attention._quantize_kv(x)
    back = attention._dequantize_kv(q, s, jnp.float32)
    err = jnp.abs(back - x) / (jnp.max(jnp.abs(x)) + 1e-9)
    assert float(err.max()) < 1.0 / 120     # half a quant step, normalized


def test_int8_cache_decode_close_to_exact():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full = model.forward(params, tokens)

    cache = model.init_cache(b, s, jnp.int8)
    assert cache["seg_dense"][0]["k"].dtype == jnp.int8
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(s):
        logits, cache = step(params, tokens[:, i:i + 1], cache)
        outs.append(logits)
    stepped = jnp.stack(outs, axis=1)
    # logits agree to quantization noise; argmax agrees almost everywhere
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=0.1, atol=0.15)
    agree = (jnp.argmax(stepped, -1) == jnp.argmax(full, -1)).mean()
    assert float(agree) >= 0.9


def test_int8_cache_memory_halves():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)

    def nbytes(dtype):
        shapes = jax.eval_shape(lambda: model.init_cache(4, 256, dtype))
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(shapes))

    import jax.numpy as jnp2
    full = nbytes(jnp2.bfloat16)
    quant = nbytes(jnp2.int8)
    assert quant < 0.6 * full
