"""Fused event engine parity: the chunked plan+scan path must replay
``run_events``' (and the legacy per-arrival Trainer's) exact update and
staleness sequence for Async, SoftSync and Staleness, with final params
matching to float tolerance; checkpoint/resume of the chunked path must
be replay-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import tiny_lm_config
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core import coordination
from repro.core.straggler import Uniform
from repro.data.synthetic_lm import SyntheticLMConfig, worker_batch
from repro.models import get_model
from repro.optim import make_optimizer, schedules
from repro.train.loop import Trainer, run_experiment

# the fused scan compiles a different XLA graph than the per-arrival
# dispatches, so params match to float tolerance, not bitwise; the
# update/staleness/selected sequences are integers and must be EXACT
TOL = dict(rtol=2e-4, atol=2e-4)


def _cfg(tmp_path, strategy, *, workers=4, updates=30, chunk=1, every=0,
         ema=0.99, **agg_kw):
    return TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 16, 4 * workers, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      **agg_kw),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.3,
                                  scale_lr_with_workers=False,
                                  ema_decay=ema),
        checkpoint=CheckpointConfig(directory=str(tmp_path),
                                    every_steps=every),
        seed=3, total_steps=updates, log_every=1, chunk_size=chunk)


def _ingredients(cfg):
    """The exact model/grad/update/batch functions the Trainer builds."""
    model = get_model(cfg.model)
    params0 = model.init(jax.random.PRNGKey(cfg.seed))
    grad_fn = coordination.make_grad_fn(model)
    sched = schedules.from_config(cfg.optimizer, cfg.aggregation.num_workers)
    opt = make_optimizer(cfg.optimizer, sched)
    # make_update_fn is usable by run_events directly now: the engine
    # tolerates the (params, opt_state, stats) return and initializes
    # opt_state through the explicit init_opt_state contract
    update_fn = coordination.make_update_fn(opt, cfg.optimizer.clip_global_norm)
    data_cfg = SyntheticLMConfig(
        vocab_size=cfg.model.vocab_size, seq_len=cfg.shape.seq_len,
        global_batch=cfg.shape.global_batch,
        num_workers=cfg.aggregation.num_workers, seed=cfg.seed)

    def batch_fn(worker, draw):
        b = worker_batch(data_cfg, worker, draw)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return params0, grad_fn, update_fn, batch_fn


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _assert_trees_close(a, b, **tol):
    for x, y in zip(_leaves(a), _leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64), **tol)


# ---------------------------------------------------------------------------
# Fused path vs the functional engine (run_events)
# ---------------------------------------------------------------------------


def test_fused_async_matches_run_events(tmp_path):
    cfg = _cfg(tmp_path, "async", workers=4, updates=30, chunk=8)
    lat = Uniform(1.0, 2.0)
    res = run_experiment(cfg, latency=lat)

    params0, grad_fn, update_fn, batch_fn = _ingredients(cfg)
    leg = coordination.run_events(
        coordination.Async(4), grad_fn, update_fn, params0, batch_fn,
        num_updates=30, latency=lat, seed=cfg.seed, ema_decay=0.99)

    assert res.steps == leg.updates
    np.testing.assert_array_equal(
        np.array([m["staleness"] for m in res.metrics]),
        leg.staleness.astype(float))
    np.testing.assert_array_equal(
        np.array([m["sim_time"] for m in res.metrics]), leg.sim_time)
    assert res.mean_staleness == pytest.approx(leg.staleness.mean())
    _assert_trees_close(res.params, leg.params, **TOL)
    _assert_trees_close(res.ema, leg.ema, **TOL)


def test_fused_softsync_matches_run_events(tmp_path):
    cfg = _cfg(tmp_path, "softsync", workers=4, updates=16, chunk=8,
               ema=0.0, softsync_c=2)
    lat = Uniform(1.0, 2.0)
    res = run_experiment(cfg, latency=lat)

    params0, grad_fn, update_fn, batch_fn = _ingredients(cfg)
    leg = coordination.run_events(
        coordination.SoftSync(4, 2), grad_fn, update_fn, params0, batch_fn,
        num_updates=16, latency=lat, seed=cfg.seed)

    assert res.steps == leg.updates
    np.testing.assert_array_equal(
        np.array([m["sim_time"] for m in res.metrics]), leg.sim_time)
    assert all(m["selected"] == 2 for m in res.metrics)
    assert res.mean_staleness == pytest.approx(leg.staleness.mean())
    _assert_trees_close(res.params, leg.params, **TOL)


def test_fused_staleness_serial_matches_run_events(tmp_path):
    """The serial-scheduler rig, ramp and jitter included: the plan's
    tau schedule and strategy-RNG draw order must mirror on_arrival."""
    cfg = _cfg(tmp_path, "staleness", workers=1, updates=14, chunk=5,
               ema=0.0, staleness_tau=3, staleness_ramp_steps=8,
               staleness_jitter=1)
    res = run_experiment(cfg)

    params0, grad_fn, update_fn, batch_fn = _ingredients(cfg)
    leg = coordination.run_events(
        coordination.Staleness(3, 8, 1), grad_fn, update_fn, params0,
        batch_fn, num_updates=14, seed=cfg.seed)

    assert res.steps == leg.updates
    np.testing.assert_array_equal(
        np.array([m["staleness"] for m in res.metrics]),
        leg.staleness.astype(float))
    _assert_trees_close(res.params, leg.params, **TOL)


def test_fused_staleness_tau0_is_serial_sgd(tmp_path):
    """tau=0: the ring is a pass-through and the scan is plain SGD."""
    cfg = _cfg(tmp_path, "staleness", workers=1, updates=8, chunk=4,
               ema=0.0, staleness_tau=0)
    res = run_experiment(cfg)
    params0, grad_fn, update_fn, batch_fn = _ingredients(cfg)
    leg = coordination.run_events(
        coordination.Staleness(0), grad_fn, update_fn, params0, batch_fn,
        num_updates=8, seed=cfg.seed)
    assert np.all(np.array([m["staleness"] for m in res.metrics]) == 0.0)
    _assert_trees_close(res.params, leg.params, **TOL)


# ---------------------------------------------------------------------------
# Fused path vs the legacy per-arrival Trainer (identical metrics stream)
# ---------------------------------------------------------------------------


def test_fused_matches_legacy_trainer_async(tmp_path):
    lat = Uniform(1.0, 2.0)
    legacy = run_experiment(_cfg(tmp_path / "legacy", "async", updates=24,
                                 chunk=1), latency=lat)
    fused = run_experiment(_cfg(tmp_path / "fused", "async", updates=24,
                                chunk=8), latency=lat)
    assert len(legacy.metrics) == len(fused.metrics)
    for ml, mf in zip(legacy.metrics, fused.metrics):
        assert ml["step"] == mf["step"]
        assert ml["selected"] == mf["selected"]
        assert ml["staleness"] == mf["staleness"]
        assert ml["sim_time"] == mf["sim_time"]
        assert ml["loss"] == pytest.approx(mf["loss"], rel=2e-4, abs=2e-4)
    assert legacy.mean_selected == fused.mean_selected
    assert legacy.mean_staleness == fused.mean_staleness
    _assert_trees_close(legacy.params, fused.params, **TOL)


def test_fused_event_failure_injection(tmp_path):
    """Kill steps force chunk boundaries; a killed worker stops arriving."""
    cfg = _cfg(tmp_path, "async", workers=4, updates=24, chunk=8)
    tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    tr.init_state()
    res = tr.run(24, kill_worker_at={10: 0})
    assert res.steps == 24
    assert 0 in tr._event_dead
    # parity with the legacy path under the same kill
    cfg1 = _cfg(tmp_path / "legacy", "async", workers=4, updates=24, chunk=1)
    t1 = Trainer(cfg1, latency=Uniform(1.0, 2.0))
    t1.init_state()
    r1 = t1.run(24, kill_worker_at={10: 0})
    np.testing.assert_array_equal(
        np.array([m["staleness"] for m in r1.metrics]),
        np.array([m["staleness"] for m in res.metrics]))
    _assert_trees_close(r1.params, res.params, **TOL)


# ---------------------------------------------------------------------------
# Checkpoint/resume replay-exactness of the chunked path
# ---------------------------------------------------------------------------


def test_fused_event_checkpoint_resume_replay_exact(tmp_path):
    """Resume of the chunked async path is bit-exact: chunk boundaries
    are forced at the checkpoint cadence, so the post-resume partition
    (and therefore the compiled scan sequence) matches the full run."""
    lat = Uniform(1.0, 2.0)
    cfg_full = _cfg(tmp_path / "full", "async", updates=20, chunk=5, every=8)
    full = run_experiment(cfg_full, latency=lat)

    cfg2 = _cfg(tmp_path / "resume", "async", updates=20, chunk=5, every=8)
    t1 = Trainer(cfg2, latency=lat)
    t1.init_state()
    t1.run(16)                              # checkpoints land at 8 and 16
    t2 = Trainer(cfg2, latency=lat)
    t2.restore_checkpoint()
    assert t2.step == 16
    r2 = t2.run(4)
    for a, b in zip(_leaves(full.params), _leaves(r2.params)):
        np.testing.assert_array_equal(a, b)
    tail_full = [m["staleness"] for m in full.metrics if m["step"] > 16]
    tail_res = [m["staleness"] for m in r2.metrics]
    assert tail_full == tail_res


def test_fused_staleness_resume_mid_ramp(tmp_path):
    """The device ring buffer round-trips through the checkpoint (FIFO
    order + version tags + strategy RNG) and resume replays exactly."""
    def cfg_at(p, every):
        return _cfg(p, "staleness", workers=1, updates=12, chunk=3,
                    every=every, ema=0.0, staleness_tau=3,
                    staleness_ramp_steps=10)

    full = run_experiment(cfg_at(tmp_path / "full", 0))
    cfg2 = cfg_at(tmp_path / "resume", 4)
    t1 = Trainer(cfg2)
    t1.init_state()
    t1.run(8)                               # ring is non-empty mid-ramp
    t2 = Trainer(cfg2)
    t2.restore_checkpoint()
    r2 = t2.run(4)
    for a, b in zip(_leaves(full.params), _leaves(r2.params)):
        np.testing.assert_array_equal(a, b)


def test_legacy_checkpoint_resumes_into_fused(tmp_path):
    """The fused path keeps the legacy on-disk format: a checkpoint
    written by the per-arrival loop restores into the chunked engine."""
    lat = Uniform(1.0, 2.0)
    legacy_full = run_experiment(
        _cfg(tmp_path / "base", "async", updates=20, chunk=1), latency=lat)

    cfg1 = _cfg(tmp_path / "x", "async", updates=20, chunk=1, every=8)
    t1 = Trainer(cfg1, latency=lat)
    t1.init_state()
    t1.run(16)
    cfg2 = _cfg(tmp_path / "x", "async", updates=20, chunk=5, every=8)
    t2 = Trainer(cfg2, latency=lat)
    t2.restore_checkpoint()
    assert t2.step == 16
    r2 = t2.run(4)
    _assert_trees_close(legacy_full.params, r2.params, **TOL)


# ---------------------------------------------------------------------------
# The explicit opt-state contract and the versioned read store
# ---------------------------------------------------------------------------


def test_run_events_explicit_opt_state_contract(tmp_path):
    """make_update_fn + run_events share one init contract: identical
    results to the legacy lazy opt_state=None closure handshake."""
    from repro.configs.base import replace
    cfg = replace(_cfg(tmp_path, "async", updates=10),
                  optimizer=OptimizerConfig(name="momentum",
                                            learning_rate=0.05,
                                            scale_lr_with_workers=False,
                                            ema_decay=0.0))
    params0, grad_fn, update_fn, batch_fn = _ingredients(cfg)
    assert callable(update_fn.init_opt_state)
    lat = Uniform(1.0, 2.0)
    explicit = coordination.run_events(
        coordination.Async(4), grad_fn, update_fn, params0, batch_fn,
        num_updates=10, latency=lat, seed=3)

    sched = schedules.from_config(cfg.optimizer, 4)
    opt = make_optimizer(cfg.optimizer, sched)
    inner = coordination.make_update_fn(opt, 0.0)

    def lazy_update(params, opt_state, grads, step):   # legacy handshake
        if opt_state is None:
            opt_state = opt.init(params)
        p, o, _ = inner(params, opt_state, grads,
                        jnp.asarray(step, jnp.int32))
        return p, o

    lazy = coordination.run_events(
        coordination.Async(4), grad_fn, lazy_update, params0, batch_fn,
        num_updates=10, latency=lat, seed=3)
    for a, b in zip(_leaves(explicit.params), _leaves(lazy.params)):
        np.testing.assert_array_equal(a, b)


def test_fused_with_model_and_batch_fn_overrides(tmp_path):
    """Non-LM rigs (the MNIST §2.1 path) route their batch_fn override
    through the fused engine's host-side chunk stacking."""
    from repro.configs.base import ModelConfig, replace
    from repro.data import mnist_like
    from repro.models import mnist_cnn

    data_cfg = mnist_like.MnistLikeConfig(num_train=256, num_test=64)
    train, _ = mnist_like.make_dataset(data_cfg)
    model = mnist_cnn.make(widths=(4, 4, 8, 8))

    def batch_fn(worker, draw):
        rng = np.random.RandomState(draw)
        idx = rng.randint(0, data_cfg.num_train, size=16)
        return {"images": jnp.asarray(train["images"][idx]),
                "labels": jnp.asarray(train["labels"][idx])}

    def cfg(chunk):
        base = _cfg(tmp_path / str(chunk), "staleness", workers=1,
                    updates=10, chunk=chunk, ema=0.0, staleness_tau=2,
                    staleness_ramp_steps=5)
        return replace(base, model=ModelConfig(name="mnist_cnn"),
                       shape=ShapeConfig("mnist", 1, 16, "train"))

    r1 = run_experiment(cfg(1), model=model, batch_fn=batch_fn)
    r4 = run_experiment(cfg(4), model=model, batch_fn=batch_fn)
    assert ([m["staleness"] for m in r1.metrics]
            == [m["staleness"] for m in r4.metrics])
    _assert_trees_close(r1.params, r4.params, **TOL)


def test_versioned_reads_shares_references():
    """Workers at the same read version share ONE tree; divergent
    versions each retain exactly one copy (the num_workers=100 fix)."""
    p0 = {"w": jnp.zeros(3)}
    store = coordination.VersionedReads(p0, num_workers=100)
    assert store.distinct_versions == 1
    assert store.read(7) is p0
    p1 = {"w": jnp.ones(3)}
    store.write(0, p1, version=1)           # one worker diverges forward
    assert store.distinct_versions == 2
    for w in range(1, 100):                 # everyone else catches up
        store.write(w, p1, version=1)
    assert store.distinct_versions == 1     # version-0 tree was released
    assert store.read(50) is p1
    store.write(3, p1, version=1)           # same-version write is a no-op
    assert store.distinct_versions == 1
