"""Strategy registry: config -> strategy round-trip for every regime."""
import pytest

from repro.configs.base import AggregationConfig
from repro.core import coordination, registry


def test_round_trip_all_regimes():
    cases = {
        "full_sync": (AggregationConfig(strategy="full_sync", num_workers=4,
                                        backup_workers=2),
                      coordination.FullSync),
        "backup": (AggregationConfig(strategy="backup", num_workers=6,
                                     backup_workers=2),
                   coordination.BackupWorkers),
        "timeout": (AggregationConfig(strategy="timeout", num_workers=4,
                                      deadline_s=1.5),
                    coordination.Timeout),
        "async": (AggregationConfig(strategy="async", num_workers=5),
                  coordination.Async),
        "softsync": (AggregationConfig(strategy="softsync", num_workers=5,
                                       softsync_c=3),
                     coordination.SoftSync),
        "staleness": (AggregationConfig(strategy="staleness", num_workers=1,
                                        staleness_tau=8,
                                        staleness_ramp_steps=10),
                      coordination.Staleness),
    }
    for name, (cfg, cls) in cases.items():
        s = registry.get_strategy(cfg)
        assert isinstance(s, cls), name
        assert s.name == name
        assert s.kind in ("mask", "event")
    # parameters survive the round trip
    s = registry.get_strategy(cases["backup"][0])
    assert (s.num_workers, s.backups, s.total_workers) == (6, 2, 8)
    s = registry.get_strategy(cases["timeout"][0])
    assert s.deadline_s == 1.5
    s = registry.get_strategy(cases["softsync"][0])
    assert (s.c, s.total_workers) == (3, 5)
    s = registry.get_strategy(cases["staleness"][0])
    assert (s.tau, s.ramp_steps, s.total_workers) == (8, 10, 1)
    # full_sync launches all N+b machines and waits for every one
    s = registry.get_strategy(cases["full_sync"][0])
    assert s.num_workers == 6


def test_unknown_strategy_lists_valid_names():
    with pytest.raises(ValueError) as exc:
        registry.get_strategy(AggregationConfig(strategy="gossip"))
    msg = str(exc.value)
    assert "gossip" in msg
    for name in ("full_sync", "backup", "timeout", "async", "softsync",
                 "staleness"):
        assert name in msg, name


def test_trainer_constructs_only_via_registry(tmp_path, monkeypatch):
    """The Trainer must build its strategy through get_strategy — no
    hand-rolled dispatch and no deprecated aggregation.from_config."""
    from repro import configs
    from repro.configs.base import (CheckpointConfig, OptimizerConfig,
                                    ShapeConfig, TrainConfig)
    from repro.core import aggregation
    from repro.train.loop import Trainer

    calls = []
    real = registry.get_strategy

    def spy(cfg):
        s = real(cfg)
        calls.append(s)
        return s

    monkeypatch.setattr(registry, "get_strategy", spy)

    def forbidden(cfg):
        raise AssertionError("Trainer must not use aggregation.from_config")

    monkeypatch.setattr(aggregation, "from_config", forbidden)

    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("t", 16, 12, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=2,
                                      backup_workers=1),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0))
    tr = Trainer(cfg)
    assert calls and tr.strategy is calls[-1]
