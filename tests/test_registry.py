"""Strategy registry: config -> strategy round-trip for every regime."""
import pytest

from repro.configs.base import AggregationConfig
from repro.core import coordination, registry


def test_round_trip_all_regimes():
    cases = {
        "full_sync": (AggregationConfig(strategy="full_sync", num_workers=4,
                                        backup_workers=2),
                      coordination.FullSync),
        "backup": (AggregationConfig(strategy="backup", num_workers=6,
                                     backup_workers=2),
                   coordination.BackupWorkers),
        "timeout": (AggregationConfig(strategy="timeout", num_workers=4,
                                      deadline_s=1.5),
                    coordination.Timeout),
        "async": (AggregationConfig(strategy="async", num_workers=5),
                  coordination.Async),
        "softsync": (AggregationConfig(strategy="softsync", num_workers=5,
                                       softsync_c=3),
                     coordination.SoftSync),
        "staleness": (AggregationConfig(strategy="staleness", num_workers=1,
                                        staleness_tau=8,
                                        staleness_ramp_steps=10),
                      coordination.Staleness),
    }
    for name, (cfg, cls) in cases.items():
        s = registry.get_strategy(cfg)
        assert isinstance(s, cls), name
        assert s.name == name
        assert s.kind in ("mask", "event")
    # parameters survive the round trip
    s = registry.get_strategy(cases["backup"][0])
    assert (s.num_workers, s.backups, s.total_workers) == (6, 2, 8)
    s = registry.get_strategy(cases["timeout"][0])
    assert s.deadline_s == 1.5
    s = registry.get_strategy(cases["softsync"][0])
    assert (s.c, s.total_workers) == (3, 5)
    s = registry.get_strategy(cases["staleness"][0])
    assert (s.tau, s.ramp_steps, s.total_workers) == (8, 10, 1)
    # full_sync launches all N+b machines and waits for every one
    s = registry.get_strategy(cases["full_sync"][0])
    assert s.num_workers == 6


def test_unknown_strategy_lists_valid_names():
    with pytest.raises(ValueError) as exc:
        registry.get_strategy(AggregationConfig(strategy="gossip"))
    msg = str(exc.value)
    assert "gossip" in msg
    for name in ("full_sync", "backup", "timeout", "async", "softsync",
                 "staleness"):
        assert name in msg, name


def test_trainer_constructs_only_via_registry(tmp_path, monkeypatch):
    """The Trainer must build its strategy through get_strategy — no
    hand-rolled dispatch and no deprecated aggregation.from_config."""
    from repro import configs
    from repro.configs.base import (CheckpointConfig, OptimizerConfig,
                                    ShapeConfig, TrainConfig)
    from repro.core import aggregation
    from repro.train.loop import Trainer

    calls = []
    real = registry.get_strategy

    def spy(cfg):
        s = real(cfg)
        calls.append(s)
        return s

    monkeypatch.setattr(registry, "get_strategy", spy)

    def forbidden(cfg):
        raise AssertionError("Trainer must not use aggregation.from_config")

    monkeypatch.setattr(aggregation, "from_config", forbidden)

    cfg = TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("t", 16, 12, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=2,
                                      backup_workers=1),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0))
    tr = Trainer(cfg)
    assert calls and tr.strategy is calls[-1]


# ---------------------------------------------------------------------------
# Plugin capability gates: strategies without event-scan / SPMD support
# must fall back to the legacy paths, never error (docs/api.md contract)
# ---------------------------------------------------------------------------


def _plugin_train_cfg(tmp_path, strategy, *, chunk_size=1, execution=None,
                      workers=3, backups=1, steps=4):
    from repro import configs
    from repro.configs.base import (CheckpointConfig, ExecutionConfig,
                                    OptimizerConfig, ShapeConfig, TrainConfig,
                                    replace)
    model = replace(configs.get_smoke_config("qwen3-0.6b"), num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, vocab_pad_multiple=16)
    return TrainConfig(
        model=model,
        shape=ShapeConfig("t", 16, 2 * (workers + backups), "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False, ema_decay=0.0),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=0),
        execution=execution or ExecutionConfig(),
        total_steps=steps, log_every=2, chunk_size=chunk_size)


@pytest.fixture
def plugin_registry():
    """Register test-local plugins; always unregister afterwards."""
    added = []

    def add(name, builder):
        registry.register(name)(builder)
        added.append(name)

    yield add
    for name in added:
        registry._BUILDERS.pop(name, None)


def test_mask_plugin_without_spmd_support_falls_back(tmp_path,
                                                     plugin_registry):
    """A mask plugin with spmd_supported=False under backend='spmd' runs
    on the simulated backend (with a warning) instead of erroring — the
    requested mesh (64 devices, far more than exist) is never built."""
    from repro.configs.base import ExecutionConfig
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    class PinnedFullSync(coordination.FullSync):
        spmd_supported = False

    plugin_registry("pinned_full_sync",
                    lambda cfg: PinnedFullSync(cfg.total_workers))
    cfg = _plugin_train_cfg(
        tmp_path, "pinned_full_sync",
        execution=ExecutionConfig(backend="spmd", mesh_data=64))
    with pytest.warns(UserWarning, match="no SPMD support"):
        tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    assert not tr._spmd
    assert not registry.supports_spmd(tr.strategy)
    tr.init_state()
    res = tr.run(4)
    assert res.steps == 4
    assert all(m["selected"] == 4 for m in res.metrics)


def test_mask_plugin_tp_opt_out_falls_back(tmp_path, plugin_registry):
    """spmd_tp_supported=False only bites when mesh_model > 1: the plugin
    keeps plain (replicated) SPMD support but falls back to the simulated
    backend when the sharded tensor-parallel path is requested."""
    from repro.configs.base import ExecutionConfig
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    class ParamPeekingFullSync(coordination.FullSync):
        spmd_tp_supported = False

    plugin_registry("param_peeking_full_sync",
                    lambda cfg: ParamPeekingFullSync(cfg.total_workers))
    strat = registry.get_strategy(_plugin_train_cfg(
        tmp_path, "param_peeking_full_sync").aggregation)
    # plain SPMD stays available; only the TP path is gated
    assert registry.supports_spmd(strat)
    assert registry.supports_spmd(
        strat, ExecutionConfig(backend="spmd", mesh_data=4))
    assert not registry.supports_spmd(
        strat, ExecutionConfig(backend="spmd", mesh_data=4, mesh_model=2))
    cfg = _plugin_train_cfg(
        tmp_path, "param_peeking_full_sync",
        execution=ExecutionConfig(backend="spmd", mesh_data=64, mesh_model=2))
    with pytest.warns(UserWarning, match="no SPMD support"):
        tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    assert not tr._spmd
    tr.init_state()
    assert tr.run(4).steps == 4


def test_event_plugin_without_scan_falls_back(tmp_path, plugin_registry):
    """An event plugin without the plan/scan protocol at chunk_size>1
    runs the legacy per-arrival path (with a warning) and produces the
    exact same result as the built-in strategy at chunk_size=1."""
    import jax
    import numpy as np
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    class NoScanAsync(coordination.Async):
        scan_supported = False

    plugin_registry("noscan_async", lambda cfg: NoScanAsync(cfg.num_workers))
    assert not registry.supports_event_scan(NoScanAsync(3))
    cfg = _plugin_train_cfg(tmp_path / "plug", "noscan_async", chunk_size=4,
                            workers=3, backups=0)
    with pytest.warns(UserWarning, match="plan/scan"):
        tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    assert not tr._event_fused
    tr.init_state()
    res = tr.run(4)
    # bit-exact with the built-in async on the per-arrival path
    ref_cfg = _plugin_train_cfg(tmp_path / "ref", "async", chunk_size=1,
                                workers=3, backups=0)
    ref = Trainer(ref_cfg, latency=Uniform(1.0, 2.0))
    ref.init_state()
    rr = ref.run(4)
    for a, b in zip(jax.tree_util.tree_leaves(res.params),
                    jax.tree_util.tree_leaves(rr.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res.sim_time == rr.sim_time


def test_spmd_event_strategy_falls_back_to_event_loop(tmp_path):
    """backend='spmd' with a built-in event regime warns and runs the
    normal event loop — supports_spmd is False for every event strategy."""
    from repro.configs.base import ExecutionConfig
    from repro.core.straggler import Uniform
    from repro.train.loop import Trainer

    cfg = _plugin_train_cfg(
        tmp_path, "async", workers=3, backups=0,
        execution=ExecutionConfig(backend="spmd", mesh_data=64))
    with pytest.warns(UserWarning, match="no SPMD support"):
        tr = Trainer(cfg, latency=Uniform(1.0, 2.0))
    assert not tr._spmd
    tr.init_state()
    assert tr.run(3).steps == 3
