"""Multi-device SPMD semantics, exercised in subprocesses with
xla_force_host_platform_device_count (the main test process keeps 1 device
per the dry-run contract)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_masked_aggregation_spmd_equals_single_device():
    """The full jitted train step on a 4x2 mesh produces the same update as
    the unsharded single-device step (masked backup aggregation included)."""
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import get_model
from repro.optim import optimizers as opt_lib, schedules
from repro.train.train_step import build_train_step, input_specs
from repro.distributed import sharding

cfg = configs.get_smoke_config("qwen3-0.6b")
shape = ShapeConfig("t", 16, 8, "train")
model = get_model(cfg)
opt = opt_lib.momentum(schedules.constant(0.1))
step_fn = build_train_step(model, opt, num_workers=4, n_aggregate=3)

params = model.init(jax.random.PRNGKey(0))
opt_state = opt.init(params)
k1, k2 = jax.random.split(jax.random.PRNGKey(1))
batch = {"tokens": jax.random.randint(k1, (8, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(k2, (8, 16), 0, cfg.vocab_size)}
mask = jnp.asarray([True, False, True, True])
step = jnp.asarray(0, jnp.int32)

# single device reference
p_ref, o_ref, _, m_ref = jax.jit(step_fn)(params, opt_state, None, step, batch, mask)

# SPMD on a 4x2 mesh
mesh = make_host_mesh(4, 2)
p_sh = sharding.param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
b_sh = sharding.batch_shardings(mesh, batch)
o_sh = sharding.opt_state_shardings(cfg, mesh, jax.eval_shape(lambda: opt_state), zero1=True)
rep = NamedSharding(mesh, P())
with use_mesh(mesh):
    jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None, rep, b_sh, rep))
    p_spmd, o_spmd, _, m_spmd = jitted(
        jax.device_put(params, p_sh), jax.device_put(opt_state, o_sh), None,
        step, jax.device_put(batch, b_sh), mask)

for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_spmd)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
assert abs(float(m_ref["loss"]) - float(m_spmd["loss"])) < 1e-4
print("SPMD == single-device: OK")
""")


def test_microbatched_step_equals_full_batch():
    """Gradient accumulation (M=4) == one big batch, masked aggregation on."""
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.configs.base import ShapeConfig
from repro.models import get_model
from repro.optim import optimizers as opt_lib, schedules
from repro.train.train_step import build_train_step

cfg = configs.get_smoke_config("minitron-4b")
model = get_model(cfg)
opt = opt_lib.sgd(schedules.constant(0.1))
full = build_train_step(model, opt, num_workers=4, n_aggregate=3)
micro = build_train_step(model, opt, num_workers=4, n_aggregate=3,
                         num_microbatches=4)
params = model.init(jax.random.PRNGKey(0))
o = opt.init(params)
k1, k2 = jax.random.split(jax.random.PRNGKey(1))
batch = {"tokens": jax.random.randint(k1, (16, 8), 0, cfg.vocab_size),
         "labels": jax.random.randint(k2, (16, 8), 0, cfg.vocab_size)}
mask = jnp.asarray([True, True, False, True])
step = jnp.asarray(0, jnp.int32)
pf, _, _, mf = jax.jit(full)(params, o, None, step, batch, mask)
pm, _, _, mm = jax.jit(micro)(params, o, None, step, batch, mask)
for a, b in zip(jax.tree_util.tree_leaves(pf), jax.tree_util.tree_leaves(pm)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5)
print("microbatch == full batch: OK")
""", devices=1)


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery end to end on an 8-device mesh: lower, compile,
    memory/cost/collective analysis for train + decode of a smoke config."""
    run_py(r"""
import jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import ShapeConfig, replace
from repro.launch import dryrun
from repro.launch.mesh import make_host_mesh, use_mesh

cfg = replace(configs.get_smoke_config("qwen3-0.6b"), dtype="bfloat16")
mesh = make_host_mesh(4, 2)
shape = ShapeConfig("t", 64, 8, "train")
low = dryrun.lower_train(cfg, shape, mesh, 4,
                         policy={"fsdp": True, "sp": True, "microbatches": 2})
comp = low.compile()
res = dryrun.analyze(comp, 0, 0)
assert res["cost"]["flops"] > 0
assert res["collectives"]["total_bytes"] > 0
assert res["memory"]["temp_bytes"] is not None

dshape = ShapeConfig("d", 64, 8, "decode")
low = dryrun.lower_decode(cfg, dshape, mesh)
comp = low.compile()
res = dryrun.analyze(comp, 0, 0)
assert res["cost"]["flops"] > 0
print("dryrun small-mesh: OK")
""")


def test_collective_parser_scan_vs_unrolled():
    """parse_collectives must recover while-loop trip counts: the scanned
    model's collective bytes ~= the unrolled model's (cost_analysis does
    NOT — that's the documented undercount this parser fixes)."""
    run_py(r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.dryrun import cost_analysis, parse_collectives
from repro.launch.mesh import make_host_mesh, use_mesh

mesh = make_host_mesh(2, 4)
D, L = 128, 12
def f_scan(ws, x):
    def body(h, w):
        return jnp.tanh(h @ w), None
    return jnp.sum(jax.lax.scan(body, x, ws)[0])
def f_unroll(ws, x):
    h = x
    for i in range(L):
        h = jnp.tanh(h @ ws[i])
    return jnp.sum(h)
ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
x = jax.ShapeDtypeStruct((64, D), jnp.float32)
sh = (NamedSharding(mesh, P(None, None, "model")), NamedSharding(mesh, P("data", None)))
with use_mesh(mesh):
    cs = jax.jit(f_scan, in_shardings=sh).lower(ws, x).compile()
    cu = jax.jit(f_unroll, in_shardings=sh).lower(ws, x).compile()
ps = parse_collectives(cs.as_text())
pu = parse_collectives(cu.as_text())
assert ps["total_bytes"] > 0
ratio = ps["total_bytes"] / max(pu["total_bytes"], 1)
assert 0.8 <= ratio <= 1.5, (ps, pu)
# the raw flop counter, by contrast, undercounts the scan by ~L
fs = cost_analysis(cs)["flops"]; fu = cost_analysis(cu)["flops"]
assert fs < fu / (L / 2)
print("collective parser: OK", ratio)
""")
