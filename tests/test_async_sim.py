"""Async-Opt / staleness simulators (paper Alg. 1/2 and §2.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import async_sim
from repro.core.straggler import Uniform


def _quadratic_problem(dim=8, seed=0):
    """Least squares: loss(w) = ||Xw - y||^2 / B — a convex sandbox."""
    rng = np.random.RandomState(seed)
    x_all = rng.randn(4096, dim).astype(np.float32)
    w_true = rng.randn(dim).astype(np.float32)
    y_all = x_all @ w_true + 0.01 * rng.randn(4096).astype(np.float32)

    def batch_fn_factory():
        def batch(worker, draw):
            r = np.random.RandomState(worker * 100003 + draw)
            idx = r.randint(0, 4096, size=32)
            return {"x": jnp.asarray(x_all[idx]), "y": jnp.asarray(y_all[idx])}
        return batch

    @jax.jit
    def grad_fn(params, batch):
        def loss(p):
            return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)
        l, g = jax.value_and_grad(loss)(params)
        return l, g

    def update_fn(params, opt_state, grads, step):
        lr = 0.05
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new, opt_state

    params0 = {"w": jnp.zeros(dim)}
    return grad_fn, update_fn, params0, batch_fn_factory(), w_true


def test_staleness_zero_is_serial_sgd():
    """tau=0 must be BIT-EXACT serial SGD."""
    grad_fn, update_fn, params0, batch, _ = _quadratic_problem()

    res = async_sim.simulate_staleness(
        grad_fn, update_fn, params0, lambda s: batch(0, s), num_updates=50,
        staleness=0)

    params = params0
    for s in range(50):
        _, g = grad_fn(params, batch(0, s))
        params, _ = update_fn(params, None, g, s)
    np.testing.assert_array_equal(np.asarray(res.params["w"]),
                                  np.asarray(params["w"]))
    assert (res.staleness == 0).all()


def test_staleness_degrades_convergence():
    """Paper Fig. 2: more staleness => worse optimum at fixed budget."""
    grad_fn, update_fn, params0, batch, w_true = _quadratic_problem()

    def final_err(tau):
        res = async_sim.simulate_staleness(
            grad_fn, update_fn, params0, lambda s: batch(0, s),
            num_updates=150, staleness=tau, ramp_steps=30)
        return float(np.linalg.norm(np.asarray(res.params["w"]) - w_true))

    errs = [final_err(tau) for tau in (0, 8, 24)]
    assert errs[0] < errs[1] < errs[2]


def test_staleness_ramp_schedule():
    assert async_sim.staleness_schedule(0, 20, 100) == 1
    assert async_sim.staleness_schedule(49, 20, 100) == 10
    assert async_sim.staleness_schedule(99, 20, 100) == 20
    assert async_sim.staleness_schedule(500, 20, 100) == 20
    assert async_sim.staleness_schedule(5, 0, 100) == 0


def test_async_staleness_tracks_worker_count():
    """Alg. 1/2: average staleness ~= number of workers (paper Table 1)."""
    grad_fn, update_fn, params0, batch, _ = _quadratic_problem()
    for w in (4, 8):
        res = async_sim.simulate_async(
            grad_fn, update_fn, params0, batch, num_workers=w,
            num_updates=300, latency=Uniform(1.0, 1.2), seed=0)
        mean_st = res.staleness[50:].mean()
        assert w - 2 <= mean_st <= w + 2, (w, mean_st)


def test_async_converges_on_convex():
    grad_fn, update_fn, params0, batch, w_true = _quadratic_problem()
    res = async_sim.simulate_async(grad_fn, update_fn, params0, batch,
                                   num_workers=4, num_updates=400,
                                   latency=Uniform(1.0, 2.0))
    err = np.linalg.norm(np.asarray(res.params["w"]) - w_true)
    assert err < 0.2
    assert res.sim_time.shape == (400,)
    assert (np.diff(res.sim_time) >= 0).all()


def test_softsync_runs_and_converges():
    grad_fn, update_fn, params0, batch, w_true = _quadratic_problem()
    res = async_sim.simulate_softsync(grad_fn, update_fn, params0, batch,
                                      num_workers=4, c=2, num_updates=200,
                                      latency=Uniform(1.0, 2.0))
    err = np.linalg.norm(np.asarray(res.params["w"]) - w_true)
    assert err < 0.5
