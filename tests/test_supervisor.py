"""Recovery supervisor: restart budget, restore fallback, rescale under
permanent deaths, and SPMD-backend chaos (subprocess)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from benchmarks.common import tiny_lm_config
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                FaultConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core import faults
from repro.core.straggler import Uniform
from repro.train import checkpoint as ckpt_lib
from repro.train.supervisor import run_supervised

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
LAT = Uniform(1.0, 2.0)


def _cfg(tmp_path, spec="", steps=16, chunk=4, every=4, max_restarts=3,
         **agg):
    agg.setdefault("backup_workers", 2)
    return TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 8, 12, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=4,
                                      **agg),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.1,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=os.path.join(str(tmp_path),
                                                           "ck"),
                                    every_steps=every),
        seed=0, total_steps=steps, chunk_size=chunk, log_every=4,
        faults=FaultConfig(spec=spec, seed=7, max_restarts=max_restarts))


def test_preempt_without_grace_restores_last_cadence_checkpoint(tmp_path):
    """grace=False dies without a checkpoint; recovery rolls back to the
    last cadence save and recomputes the lost steps."""
    spec = "preempt@10"
    cfg = _cfg(tmp_path, spec=spec)
    inj = faults.FaultInjector(faults.FaultPlan(
        (faults.FaultEvent("preempt", 10, grace=False),), seed=7))
    res = run_supervised(cfg, latency=LAT, injector=inj)
    assert res.steps == 16
    restore = [e for e in res.recovery_log if e["event"] == "restore"]
    assert restore == [{"event": "restore", "step": 8, "attempt": 1}]


def test_restart_budget_exhaustion_gives_up(tmp_path):
    """More preemptions than the budget: the supervisor logs give_up and
    re-raises the Preemption."""
    inj = faults.FaultInjector(faults.FaultPlan(
        tuple(faults.FaultEvent("preempt", s, grace=False)
              for s in (3, 5, 7)), seed=0))
    cfg = _cfg(tmp_path, max_restarts=1, every=0)   # no cadence saves
    with pytest.raises(faults.Preemption) as ei:
        run_supervised(cfg, latency=LAT, injector=inj)
    assert inj.log[-1]["event"] == "give_up"
    assert inj.log[-1]["restarts"] == 2
    # the structured log is surfaced on the exception, not lost with the
    # run: the caller's postmortem sees every recovery action
    assert ei.value.recovery_log == list(inj.log)
    assert ei.value.recovery_log[-1]["event"] == "give_up"
    assert any(e["event"] == "restore" for e in ei.value.recovery_log)


def test_recovery_without_any_checkpoint_restarts_fresh(tmp_path):
    """Preempt before the first cadence save: nothing on disk, recovery is
    a from-scratch restart that still completes."""
    inj = faults.FaultInjector(faults.FaultPlan(
        (faults.FaultEvent("preempt", 2, grace=False),), seed=0))
    cfg = _cfg(tmp_path, every=0, steps=8)
    res = run_supervised(cfg, latency=LAT, injector=inj)
    assert res.steps == 8
    assert {"event": "restore", "step": 0, "attempt": 1} in res.recovery_log


def test_ckpt_io_exhausting_retries_is_recovered(tmp_path):
    """A write failure burst larger than the retry budget kills the run
    (InjectedIOError propagates); the supervisor restores and finishes."""
    cfg = replace(_cfg(tmp_path, steps=16),
                  checkpoint=CheckpointConfig(
                      directory=os.path.join(str(tmp_path), "ck"),
                      every_steps=4, write_retries=1, retry_backoff_s=0.0))
    inj = faults.FaultInjector(faults.FaultPlan(
        (faults.FaultEvent("ckpt_io", 5, fails=5),), seed=0))
    res = run_supervised(cfg, latency=LAT, injector=inj)
    assert res.steps == 16
    events = [e["event"] for e in res.recovery_log]
    assert "ckpt_io_fault" in events and "restore" in events
    # the good checkpoint that recovery used predates the failed save
    assert any(e["event"] == "restore" and e["step"] <= 4
               for e in res.recovery_log)


def test_permanent_deaths_trigger_rescale_under_supervision(tmp_path):
    """Crashes past the backup pool: the elastic layer shrinks the
    cluster (paper A.3 lr rule) and the run still completes."""
    cfg = _cfg(tmp_path, spec="crash@3:w0,crash@5:w1,crash@7:w2", steps=16)
    res = run_supervised(cfg, latency=LAT)
    assert res.steps == 16
    events = [e["event"] for e in res.recovery_log]
    assert events.count("worker_crash") == 3
    assert "rescale" in events
    [rs] = [e for e in res.recovery_log if e["event"] == "rescale"]
    assert rs["to_workers"] < rs["from_workers"]
    assert np.isfinite(res.metrics[-1]["loss"])


def test_corrupt_latest_checkpoint_walks_back_on_recovery(tmp_path):
    """The newest checkpoint is corrupted between crash and restore: the
    supervisor's find_good_step walks back to the previous one."""
    inj = faults.FaultInjector(faults.FaultPlan(
        (faults.FaultEvent("preempt", 10, grace=True),), seed=0))
    cfg = _cfg(tmp_path, steps=16)

    # corrupt the grace checkpoint the moment it is committed (the
    # "preempt" record fires right after the grace save, before the
    # supervisor's find_good_step runs)
    orig_record = inj.record

    def record_and_corrupt(event, **kw):
        if event == "preempt":
            p = os.path.join(cfg.checkpoint.directory, "step_00000010",
                             "arrays.npz")
            with open(p, "wb") as f:
                f.write(b"garbage")
        orig_record(event, **kw)

    inj.record = record_and_corrupt
    res = run_supervised(cfg, latency=LAT, injector=inj)
    assert res.steps == 16
    [restore] = [e for e in res.recovery_log if e["event"] == "restore"]
    assert restore["step"] == 8          # walked past the corrupt step 10


def test_supervised_spmd_chaos_subprocess(tmp_path):
    """The chaos acceptance run on the SPMD backend (8 forced host
    devices): crash + slowdown + preempt complete under supervision with
    the same recovery log as the simulated backend."""
    code = f"""
import os
from benchmarks.common import tiny_lm_config
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                ExecutionConfig, FaultConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import Uniform
from repro.train.supervisor import run_supervised

def cfg(sub, backend):
    return TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 8, 12, "train"),
        aggregation=AggregationConfig(strategy="backup", num_workers=4,
                                      backup_workers=2),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.1,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory={str(tmp_path)!r} + "/" + sub,
                                    every_steps=4),
        execution=ExecutionConfig(backend=backend, mesh_data=6),
        seed=0, total_steps=16, chunk_size=4, log_every=4,
        faults=FaultConfig(spec="crash@5:w1,slow@3:w0,preempt@10", seed=7))

lat = Uniform(1.0, 2.0)
r_spmd = run_supervised(cfg("spmd", "spmd"), latency=lat)
r_sim = run_supervised(cfg("sim", "sim"), latency=lat)
assert r_spmd.steps == r_sim.steps == 16
assert r_spmd.recovery_log == r_sim.recovery_log
assert any(e["event"] == "restore" for e in r_spmd.recovery_log)
print("SPMD-CHAOS-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (SRC + os.pathsep
                         + os.path.join(SRC, "..") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "SPMD-CHAOS-OK" in out.stdout
