"""Gradient compression: error bounds + error-feedback telescoping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.distributed import compression as comp


def _tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"a": scale * jax.random.normal(k1, (64,)),
            "b": {"c": scale * jax.random.normal(k2, (8, 8))}}


def test_bf16_roundtrip_error():
    t = _tree()
    rt = comp.decompress_bf16(comp.compress_bf16(t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2)


@given(scale=st.floats(1e-3, 1e3))
@settings(max_examples=10, deadline=None)
def test_int8_error_bound(scale):
    """Quantization error <= scale_step/2 = max|g|/254 per element."""
    t = _tree(scale=scale)
    rt = comp.decompress_int8(comp.compress_int8(t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(rt)):
        bound = float(jnp.abs(a).max()) / 127.0 * 0.51
        assert float(jnp.abs(a - b).max()) <= bound + 1e-9


def test_error_feedback_telescopes():
    """sum_t deq(q_t) -> sum_t g_t : the residual is carried, so the total
    applied update differs from the true sum only by the FINAL residual."""
    grads = [_tree(seed=i) for i in range(20)]
    e = comp.init_error_feedback(grads[0])
    applied = jax.tree_util.tree_map(jnp.zeros_like, grads[0])
    true_sum = jax.tree_util.tree_map(jnp.zeros_like, grads[0])
    for g in grads:
        c, e = comp.compress_with_error_feedback(g, e)
        deq = comp.decompress_int8(c)
        applied = jax.tree_util.tree_map(jnp.add, applied, deq)
        true_sum = jax.tree_util.tree_map(jnp.add, true_sum, g)
    for a, t, r in zip(jax.tree_util.tree_leaves(applied),
                       jax.tree_util.tree_leaves(true_sum),
                       jax.tree_util.tree_leaves(e)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(t - r),
                                   rtol=1e-4, atol=1e-5)
        # and the residual is bounded by one quantization step
        assert float(jnp.abs(r).max()) < 0.2


def test_compressed_bytes_accounting():
    t = _tree()
    n = 64 + 64
    assert comp.compressed_bytes(t, "none") == 4 * n
    assert comp.compressed_bytes(t, "bf16") == 2 * n
    assert comp.compressed_bytes(t, "int8_ef") == n + 8
    with pytest.raises(ValueError):
        comp.compressed_bytes(t, "fp4")
