"""Sharding rule unit tests (pure spec logic — no devices needed)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed.sharding import param_spec, zero1_spec


def _spec(arch, path, shape, model=16):
    return param_spec(path, shape, configs.get_config(arch), model)


def test_vocab_sharding():
    # qwen3 padded vocab 151936 % 16 == 0 -> sharded
    assert _spec("qwen3-0.6b", "embed/embedding", (152064, 1024)) == \
        P("model", None)
    assert _spec("rwkv6-1.6b", "head/w", (2048, 65536)) == P(None, "model")


def test_attention_head_divisibility_guard():
    # gemma3: 4 q heads * 256 = 1024 % 16 == 0 -> sharded on proj dim
    assert _spec("gemma3-1b", "seg_dense/attn/wq/w", (26, 1152, 1024)) == \
        P(None, None, "model")
    # but kv proj = 1*256 = 256 % 16 == 0 -> sharded; head_dim 250 would not be
    assert _spec("gemma3-1b", "seg_dense/attn/wk/w", (26, 1152, 256)) == \
        P(None, None, "model")
    # hymba: 25 heads * 64 = 1600 % 16 == 0 -> ok; kv 5*64=320 % 16 == 0
    assert _spec("hymba-1.5b", "blocks/attn/wo/w", (32, 1600, 1600)) == \
        P(None, "model", None)
    # a genuinely non-divisible dim stays replicated
    assert _spec("gemma3-1b", "seg_dense/attn/wq/w", (26, 1152, 1000)) == \
        P(None, None, None)


def test_mlp_tp():
    assert _spec("qwen3-0.6b", "seg_dense/mlp/w_up/w", (28, 1024, 3072)) == \
        P(None, None, "model")
    assert _spec("qwen3-0.6b", "seg_dense/mlp/w_down/w", (28, 3072, 1024)) == \
        P(None, "model", None)


def test_moe_partition_modes():
    # qwen2-moe: tp mode -> expert d_ff sharded
    assert _spec("qwen2-moe-a2.7b", "seg_moe/moe/w_gate/w",
                 (24, 60, 2048, 1408)) == P(None, None, None, "model")
    assert _spec("qwen2-moe-a2.7b", "seg_moe/moe/w_down/w",
                 (24, 60, 1408, 2048)) == P(None, None, "model", None)
    # deepseek: ep mode -> expert dim sharded (64 % 16 == 0)
    assert _spec("deepseek-v2-lite-16b", "seg_moe/moe/w_gate/w",
                 (26, 64, 2048, 1408)) == P(None, "model", None, None)
    assert _spec("deepseek-v2-lite-16b", "seg_moe/moe/router/w",
                 (26, 2048, 64)) == P(None, None, None)


def test_norms_replicated():
    assert _spec("qwen3-0.6b", "seg_dense/ln1/scale", (28, 1024)) == \
        P(None, None)
    assert _spec("qwen3-0.6b", "final_norm/scale", (1024,)) == P(None)


def test_zero1_spec_picks_divisible_dim():
    # dim0 = 28 not divisible by 16 -> falls through to dim1
    s = zero1_spec(P(None, None, "model"), (28, 1024, 3072), ("data",), 16)
    assert s == P(None, "data", "model")
    # divisible layer dim is taken first by default...
    s = zero1_spec(P(None, None, "model"), (32, 1024, 3072), ("data",), 16)
    assert s == P("data", None, "model")
    # ...but prefer_inner (FSDP) skips it so gathers stream per layer
    s = zero1_spec(P(None, None, "model"), (32, 1024, 3072), ("data",), 16,
                   prefer_inner=True)
    assert s == P(None, "data", "model")
    # nothing divisible -> unchanged
    s = zero1_spec(P(None,), (7,), ("data",), 16)
    assert s == P(None,)
    # multi-axis data
    s = zero1_spec(P(None, "model"), (64, 3072), ("pod", "data"), 32)
    assert s == P(("pod", "data"), "model")


def test_mla_projections():
    assert _spec("deepseek-v2-lite-16b", "seg_moe/attn/wkv_b/w",
                 (26, 512, 4096)) == P(None, None, "model")
    assert _spec("deepseek-v2-lite-16b", "seg_moe/attn/wo/w",
                 (26, 2048, 2048)) == P(None, "model", None)
