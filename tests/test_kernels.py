"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import mamba, rwkv6


def _rand(key, shape, dtype):
    return (0.5 * jax.random.normal(key, shape)).astype(dtype)


@pytest.mark.parametrize("s,d,h,kv,bq,bk", [
    (128, 64, 4, 4, 64, 64),       # MHA
    (256, 64, 4, 2, 128, 64),      # GQA 2:1
    (256, 128, 8, 1, 64, 128),     # MQA
    (128, 32, 2, 2, 128, 128),     # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, d, h, kv, bq, bk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(keys[0], (2, s, h, d), dtype)
    k = _rand(keys[1], (2, s, kv, d), dtype)
    v = _rand(keys[2], (2, s, kv, d), dtype)
    out = ops.flash_attention_bshd(q, k, v, block_q=bq, block_k=bk)
    expect = ref.reference_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,softcap", [(32, 0.0), (0, 30.0), (64, 20.0)])
def test_flash_attention_window_softcap(window, softcap):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(keys[0], (1, 256, 4, 64), jnp.float32)
    k = _rand(keys[1], (1, 256, 2, 64), jnp.float32)
    v = _rand(keys[2], (1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention_bshd(q, k, v, window=window, softcap=softcap,
                                   block_q=64, block_k=64)
    expect = ref.reference_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window,
        softcap=softcap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def _wkv_inputs(b, s, h, d, seed=0, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = _rand(keys[0], (b, s, h, d), dtype)
    k = _rand(keys[1], (b, s, h, d), dtype)
    v = _rand(keys[2], (b, s, h, d), dtype)
    w_log = jnp.clip(jax.random.normal(keys[3], (b, s, h, d)) - 1.0, -8.0, 1.6)
    w = jnp.exp(-jnp.exp(w_log)).astype(dtype)
    u = _rand(keys[4], (h, d), jnp.float32)
    return r, k, v, w, u


@pytest.mark.parametrize("s,d,chunk", [(64, 16, 16), (128, 32, 16), (48, 16, 8)])
def test_wkv6_kernel_vs_ref(s, d, chunk):
    r, k, v, w, u = _wkv_inputs(2, s, 2, d)
    out = ops.wkv6(r, k, v, w, u, chunk=chunk)
    expect, _ = ref.reference_wkv6(*(t.transpose(0, 2, 1, 3)
                                     for t in (r, k, v, w)), u)
    scale = float(jnp.abs(expect).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(expect.transpose(0, 2, 1, 3)),
                               rtol=1e-4, atol=1e-4 * scale)


def test_model_wkv_chunked_matches_scan():
    """The jnp chunked training path == the sequential oracle, with state
    carry across calls (decode continuation)."""
    r, k, v, w, u = _wkv_inputs(2, 80, 2, 16, seed=3)
    rt, kt, vt, wt = (t for t in (r, k, v, w))
    o1, s1 = rwkv6.wkv_chunked(rt, kt, vt, wt, u)
    o2, s2 = rwkv6.wkv_scan(rt, kt, vt, wt, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
    # continuation: chunked(first half) state feeds scan(second half)
    oa, sa = rwkv6.wkv_chunked(rt[:, :40], kt[:, :40], vt[:, :40],
                               wt[:, :40], u)
    ob, sb = rwkv6.wkv_scan(rt[:, 40:], kt[:, 40:], vt[:, 40:], wt[:, 40:],
                            u, state=sa)
    np.testing.assert_allclose(np.asarray(ob), np.asarray(o2[:, 40:]),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_scan():
    b, s, h, p, n = 2, 96, 3, 8, 4
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    xv = 0.5 * jax.random.normal(keys[0], (b, s, h, p))
    bb = 0.5 * jax.random.normal(keys[1], (b, s, h, n))
    cc = 0.5 * jax.random.normal(keys[2], (b, s, h, n))
    dt = jax.nn.softplus(jax.random.normal(keys[3], (b, s, h)))
    decay = jnp.exp(-dt * jnp.exp(jax.random.normal(keys[4], (h,)) * 0.3))
    dskip = jnp.ones((h, p))
    o1, s1 = mamba.ssd_chunked(xv, bb, cc, dt, decay, dskip, chunk=32)
    o2, s2 = mamba.ssd_scan(xv, bb, cc, dt, decay, dskip)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("w,n,block", [(8, 4096, 1024), (16, 8192, 4096),
                                       (3, 512, 512),
                                       # non-multiple sizes: kernel pads the
                                       # flattened grad to the block multiple
                                       (8, 5000, 1024), (4, 700, 256),
                                       (5, 3, 4096)])
def test_backup_reduce_kernel(w, n, block):
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(w, n), jnp.float32)
    mask = jnp.asarray(rng.rand(w) < 0.75)
    n_agg = max(1, int(mask.sum()))
    out = ops.backup_reduce(g, mask, n_agg, block=block)
    expect = ref.reference_backup_reduce(g, mask, n_agg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_backup_reduce_matches_sync_backup_semantics():
    """Kernel == repro.core.sync_backup.aggregate_masked on flattened grads."""
    from repro.core import sync_backup
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(6, 2048), jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    out = ops.backup_reduce(g, mask, 4, block=512)
    expect = sync_backup.aggregate_masked(g, mask, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)
