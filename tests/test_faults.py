"""Chaos engine: plan determinism, fault application across backends,
multi-kill back-compat, and the dynamic_backup adaptive strategy."""
import os

import numpy as np
import pytest

from benchmarks.common import tiny_lm_config
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                FaultConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig, replace)
from repro.core import faults, registry
from repro.core.coordination import DynamicBackup
from repro.core.straggler import Uniform
from repro.train.loop import run_experiment
from repro.train.supervisor import run_supervised


# ---------------------------------------------------------------------------
# FaultPlan / spec parsing
# ---------------------------------------------------------------------------


def test_plan_from_spec_explicit_and_random():
    plan = faults.plan_from_spec("crash@5:w1,slow@3:w0,ckpt_io@7,preempt@9",
                                 num_steps=20, num_workers=4)
    kinds = [(e.kind, e.step, e.worker) for e in plan.events]
    assert kinds == [("slowdown", 3, 0), ("crash", 5, 1),
                     ("ckpt_io", 7, -1), ("preempt", 9, -1)]
    # count form draws seeded-random placements, deterministically
    p1 = faults.plan_from_spec("crash=2,slow=3", num_steps=50, num_workers=8,
                               seed=11)
    p2 = faults.plan_from_spec("crash=2,slow=3", num_steps=50, num_workers=8,
                               seed=11)
    assert p1 == p2
    assert len(p1) == 5
    p3 = faults.plan_from_spec("crash=2,slow=3", num_steps=50, num_workers=8,
                               seed=12)
    assert p1 != p3


def test_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.plan_from_spec("meteor@3", num_steps=10, num_workers=2)


def test_unknown_kind_error_lists_valid_kinds():
    """Mirror registry.get_strategy: the error names every valid kind and
    alias, so a typo is a one-read fix."""
    with pytest.raises(ValueError) as ei:
        faults.plan_from_spec("meteor@3", num_steps=10, num_workers=2)
    msg = str(ei.value)
    for kind in faults.FAULT_KINDS:
        assert kind in msg
    assert "kill=crash" in msg and "slow=slowdown" in msg
    assert "meteor" in msg and "'meteor@3'" in msg
    with pytest.raises(ValueError, match="valid kinds"):
        faults.FaultEvent("meteor", 3)


def test_replica_scope_spec_grammar():
    plan = faults.plan_from_spec(
        "crash@4:r1,slowdown@0:r0:x8:d32,restart@20:r1",
        num_steps=64, num_workers=3, num_replicas=3)
    ev = {(e.kind, e.step): e for e in plan.events}
    assert ev[("crash", 4)].replica == 1
    assert ev[("crash", 4)].worker == -1
    slow = ev[("slowdown", 0)]
    assert (slow.replica, slow.factor, slow.duration) == (0, 8.0, 32)
    assert ev[("restart", 20)].replica == 1
    # random placement draws replicas (seeded) under replica scope
    p1 = faults.plan_from_spec("crash=3", num_steps=50, num_workers=4,
                               seed=5, num_replicas=4)
    p2 = faults.plan_from_spec("crash=3", num_steps=50, num_workers=4,
                               seed=5, num_replicas=4)
    assert p1 == p2
    assert all(0 <= e.replica < 4 and e.worker == -1 for e in p1.events)


def test_spec_field_errors():
    with pytest.raises(ValueError, match="both a worker .* and a replica"):
        faults.plan_from_spec("crash@4:w1:r2", num_steps=10, num_workers=2)
    with pytest.raises(ValueError, match="duplicate fault spec field"):
        faults.plan_from_spec("slow@4:x2:x3", num_steps=10, num_workers=2)
    with pytest.raises(ValueError, match="bad fault spec field"):
        faults.plan_from_spec("crash@4:q7", num_steps=10, num_workers=2)
    # known key but non-numeric suffix: structured message, not a bare
    # float() ValueError
    with pytest.raises(ValueError, match="bad fault spec field"):
        faults.plan_from_spec("crash@5:wa", num_steps=10, num_workers=2)


def test_training_scope_rng_stream_unchanged_by_replica_fields():
    """num_replicas=0 (every training call site) must keep the legacy
    draw order: ckpt_io/preempt still consume a worker draw before being
    forced to -1, so existing seeded plans are byte-stable."""
    p = faults.plan_from_spec("crash=1,ckpt_io=1,slow=1", num_steps=40,
                              num_workers=6, seed=3)
    q = faults.plan_from_spec("crash=1,ckpt_io=1,slow=1", num_steps=40,
                              num_workers=6, seed=3)
    assert p == q
    by_kind = {e.kind: e for e in p.events}
    assert by_kind["ckpt_io"].worker == -1
    assert by_kind["crash"].worker >= 0
    assert all(e.replica == -1 for e in p.events)


def test_injector_fires_at_most_once():
    plan = faults.plan_from_spec("crash@5:w1", num_steps=10, num_workers=4)
    inj = faults.FaultInjector(plan)
    assert [e.kind for e in inj.take_due(5)] == ["crash"]
    assert inj.take_due(5) == []       # popped: a restart does not replay
    assert inj.take_due(9) == []


def test_injector_upcoming_steps_cover_slow_windows():
    plan = faults.plan_from_spec("slow@3:w0", num_steps=20, num_workers=4)
    inj = faults.FaultInjector(plan)
    assert inj.upcoming_steps() == [3]
    [ev] = inj.take_due(3)
    inj.note_slowdown(3, ev.worker, ev.factor, ev.duration)
    # the window's end is now a forced chunk boundary
    assert inj.upcoming_steps() == [3 + ev.duration]


# ---------------------------------------------------------------------------
# End-to-end chaos runs
# ---------------------------------------------------------------------------


def _cfg(tmp_path, strategy="backup", spec="", chunk=4, steps=16, seed=0,
         fault_seed=7, every=4, **agg):
    if strategy in ("backup", "dynamic_backup"):
        agg.setdefault("backup_workers", 2)
    return TrainConfig(
        model=tiny_lm_config(),
        shape=ShapeConfig("t", 8, 12, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=4, **agg),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.1,
                                  scale_lr_with_workers=False),
        checkpoint=CheckpointConfig(directory=os.path.join(str(tmp_path),
                                                           "ck"),
                                    every_steps=every),
        seed=seed, total_steps=steps, chunk_size=chunk, log_every=4,
        faults=FaultConfig(spec=spec, seed=fault_seed))


LAT = Uniform(1.0, 2.0)
SPEC = "crash@5:w1,slow@3:w0,ckpt_io@7,preempt@10"


def test_chaos_mask_mode_completes_with_identical_logs(tmp_path):
    """The acceptance run: crashes + slowdowns + ckpt-write failures +
    preemption complete under the supervisor, the final loss lands near
    the fault-free run, and two same-seed runs log bit-identically."""
    clean = run_experiment(_cfg(tmp_path / "clean"), latency=LAT)
    r1 = run_supervised(_cfg(tmp_path / "a", spec=SPEC), latency=LAT)
    r2 = run_supervised(_cfg(tmp_path / "b", spec=SPEC), latency=LAT)
    assert r1.steps == clean.steps == 16
    assert r1.recovery_log and r1.recovery_log == r2.recovery_log
    events = [e["event"] for e in r1.recovery_log]
    for expected in ("worker_crash", "worker_slowdown", "ckpt_io_fault",
                     "ckpt_write_retry", "preempt", "restore"):
        assert expected in events, f"missing {expected} in {events}"
    assert abs(r1.metrics[-1]["loss"] - clean.metrics[-1]["loss"]) < 0.5


@pytest.mark.parametrize("chunk", [1, 4])
def test_chaos_event_mode_fused_matches_legacy(tmp_path, chunk):
    """Crash/slowdown/restart/preempt in event mode: the fused scan and
    the per-arrival loop recover to the identical final loss and log."""
    spec = "crash@5:w1,slow@3:w0,restart@9:w1,preempt@12"
    res = run_supervised(
        _cfg(tmp_path / f"c{chunk}", strategy="async", spec=spec,
             chunk=chunk), latency=LAT)
    assert res.steps == 16
    events = [e["event"] for e in res.recovery_log]
    assert events.count("worker_crash") == 1
    assert events.count("worker_restart") == 1
    assert "preempt" in events and "restore" in events
    assert np.isfinite(res.metrics[-1]["loss"])


def test_chaos_event_fused_vs_legacy_same_loss(tmp_path):
    spec = "crash@5:w1,slow@3:w0"
    r_legacy = run_experiment(_cfg(tmp_path / "l", strategy="async",
                                   spec=spec, chunk=1), latency=LAT)
    r_fused = run_experiment(_cfg(tmp_path / "f", strategy="async",
                                  spec=spec, chunk=4), latency=LAT)
    assert r_legacy.recovery_log == r_fused.recovery_log
    np.testing.assert_allclose(r_legacy.metrics[-1]["loss"],
                               r_fused.metrics[-1]["loss"], rtol=1e-5)


def test_slowdown_shifts_masks_not_streams(tmp_path):
    """A slowdown spike changes who gets selected while active, and the
    post-window arrivals return to the fault-free stream (multiplier
    composes after sampling — the replay contract)."""
    r0 = run_experiment(_cfg(tmp_path / "h", spec=""), latency=LAT)
    r1 = run_experiment(_cfg(tmp_path / "s", spec="slow@2:w0"), latency=LAT)
    assert r1.sim_time >= r0.sim_time   # the spike can only slow the run
    [ev] = [e for e in r1.recovery_log if e["event"] == "worker_slowdown"]
    assert (ev["step"], ev["worker"], ev["factor"]) == (2, 0, 4.0)
    assert ev["until"] > 2


def test_kill_worker_at_accepts_lists(tmp_path):
    """Satellite: correlated outages — {step: [w, w]} kills both; the
    scalar form keeps working."""
    cfg = _cfg(tmp_path / "m", spec="", every=0)
    r = run_experiment(cfg, latency=LAT, kill_worker_at={3: [4, 5]})
    assert r.steps == 16
    cfg2 = _cfg(tmp_path / "s2", spec="", every=0)
    r2 = run_experiment(cfg2, latency=LAT, kill_worker_at={3: 4})
    assert r2.steps == 16


def test_faults_require_host_backend(tmp_path):
    cfg = replace(_cfg(tmp_path, spec="crash@3:w0"),
                  straggler_backend="device")
    with pytest.raises(ValueError, match="host"):
        run_experiment(cfg, latency=LAT)


def test_faults_reject_serial_rigs(tmp_path):
    cfg = _cfg(tmp_path, strategy="staleness", spec="crash@3:w0", chunk=1,
               staleness_tau=1)
    with pytest.raises(ValueError, match="serial"):
        run_experiment(cfg, latency=LAT)


# ---------------------------------------------------------------------------
# dynamic_backup
# ---------------------------------------------------------------------------


def test_dynamic_backup_registered():
    cfg = AggregationConfig(strategy="dynamic_backup", num_workers=4,
                            backup_workers=2, dynamic_window=16)
    s = registry.get_strategy(cfg)
    assert isinstance(s, DynamicBackup)
    assert s.total_workers == 6 and s.n == 4
    assert registry.supports_spmd(s)


def test_dynamic_backup_adapts_to_straggler_tail():
    """A heavy tail (one worker 50x slower) drives the cutoff below full
    sync; a uniform healthy cluster drives it up to full sync."""
    s = DynamicBackup(num_workers=6, backups=0, window=8)
    rng = np.random.RandomState(0)
    for _ in range(16):
        arr = rng.uniform(1.0, 1.2, size=6)
        arr[5] *= 50.0                       # a persistent heavy straggler
        s.select(arr)
    assert s.n <= 5, f"tail not cut: n={s.n}"
    s2 = DynamicBackup(num_workers=4, backups=2, window=8)
    for _ in range(16):
        s2.select(rng.uniform(1.0, 1.05, size=6))
    assert s2.n == 6, f"healthy cluster should full-sync: n={s2.n}"


def test_dynamic_backup_routes_around_dead_workers():
    """+inf arrivals (crashes) zero out infeasible cutoffs with no special
    casing; selection clamps to the live count immediately."""
    s = DynamicBackup(num_workers=4, backups=0, window=4)
    arr = np.array([1.0, 1.1, 1.2, np.inf])
    mask, t = s.select(arr)
    assert mask.sum() == 3 and np.isfinite(t)
    for _ in range(6):
        s.select(np.array([1.0, 1.1, 1.2, np.inf]))
    assert s.n <= 3


def test_dynamic_backup_state_roundtrip():
    s = DynamicBackup(num_workers=4, backups=2, window=8)
    rng = np.random.RandomState(3)
    for _ in range(5):
        s.select(rng.uniform(1, 2, size=6))
    d = s.state_dict()
    s2 = DynamicBackup(num_workers=4, backups=2, window=8)
    s2.load_state_dict(d)
    arr = rng.uniform(1, 2, size=6)
    m1, t1 = s.select(arr.copy())
    m2, t2 = s2.select(arr.copy())
    np.testing.assert_array_equal(m1, m2)
    assert t1 == t2 and s.n == s2.n


def test_dynamic_backup_checkpoint_resume_keeps_adapted_n(tmp_path):
    """The adapted cutoff survives save/restore via manifest
    strategy_state (a restored run does not re-learn from scratch)."""
    from repro.train.loop import Trainer
    cfg = _cfg(tmp_path, strategy="dynamic_backup", chunk=1, steps=12,
               dynamic_window=6)
    tr = Trainer(cfg, latency=Uniform(1.0, 4.0))
    tr.init_state()
    tr.run(8)
    tr.save_checkpoint()
    n_saved = tr.strategy.n
    tr2 = Trainer(cfg, latency=Uniform(1.0, 4.0))
    tr2.restore_checkpoint()
    assert tr2.strategy.n == n_saved
    assert len(tr2.strategy.history) == len(tr.strategy.history)


def test_dynamic_backup_rejects_device_backend(tmp_path):
    cfg = replace(_cfg(tmp_path, strategy="dynamic_backup"),
                  straggler_backend="device")
    with pytest.raises(ValueError, match="host"):
        run_experiment(cfg, latency=LAT)
