"""Fused chunked trainer == legacy per-step trainer, bit for bit.

The chunked path (cfg.chunk_size > 1, 'host' straggler backend) must
produce bit-identical params / opt_state / ema / sim_time / metrics to the
legacy loop — including across checkpoint/restore boundaries and kill
injections — because the scan body is the same jitted step function and
the host straggler streams are untouched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import (AggregationConfig, CheckpointConfig,
                                OptimizerConfig, ShapeConfig, TrainConfig)
from repro.core.straggler import PaperCalibrated, Uniform
from repro.train.loop import Trainer


def _cfg(tmp_path, chunk_size=1, strategy="backup", workers=4, backups=2,
         ckpt_every=0, backend="host", ema=0.999):
    return TrainConfig(
        model=configs.get_smoke_config("qwen3-0.6b"),
        shape=ShapeConfig("t", 16, 24, "train"),
        aggregation=AggregationConfig(strategy=strategy, num_workers=workers,
                                      backup_workers=backups, deadline_s=0.4),
        optimizer=OptimizerConfig(name="momentum", learning_rate=0.05,
                                  scale_lr_with_workers=False,
                                  ema_decay=ema),
        checkpoint=CheckpointConfig(directory=str(tmp_path),
                                    every_steps=ckpt_every),
        log_every=3, chunk_size=chunk_size, straggler_backend=backend)


def _trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _run_pair(tmp_path, steps, chunk, kills=None, **kw):
    tr_legacy = Trainer(_cfg(tmp_path / "legacy", chunk_size=1, **kw),
                        latency=Uniform(1.0, 2.0))
    tr_legacy.init_state()
    r1 = tr_legacy.run(steps, kill_worker_at=kills)
    tr_chunk = Trainer(_cfg(tmp_path / "chunk", chunk_size=chunk, **kw),
                       latency=Uniform(1.0, 2.0))
    tr_chunk.init_state()
    r2 = tr_chunk.run(steps, kill_worker_at=kills)
    return tr_legacy, tr_chunk, r1, r2


def test_chunked_bit_identical_to_legacy(tmp_path):
    """17 steps with chunk_size=8 exercises full chunks + a ragged tail."""
    tr1, tr2, r1, r2 = _run_pair(tmp_path, steps=17, chunk=8)
    assert _trees_equal(tr1.params, tr2.params)
    assert _trees_equal(tr1.opt_state, tr2.opt_state)
    assert _trees_equal(tr1.ema, tr2.ema)
    assert r1.sim_time == r2.sim_time          # bit-exact, not approx
    assert r1.metrics == r2.metrics


@pytest.mark.parametrize("strategy,backups", [("full_sync", 0),
                                              ("timeout", 0)])
def test_chunked_bit_identical_other_strategies(tmp_path, strategy, backups):
    tr1, tr2, r1, r2 = _run_pair(tmp_path, steps=9, chunk=4,
                                 strategy=strategy, backups=backups)
    assert _trees_equal(tr1.params, tr2.params)
    assert r1.sim_time == r2.sim_time
    assert r1.metrics == r2.metrics


def test_chunked_across_checkpoint_restore_boundary(tmp_path):
    """Chunk boundaries are forced at the checkpoint cadence, and a trainer
    restored from a mid-run checkpoint continues bit-identically on the
    chunked path."""
    kw = dict(ckpt_every=5)
    tr1, tr2, r1, r2 = _run_pair(tmp_path, steps=13, chunk=8, **kw)
    assert _trees_equal(tr1.params, tr2.params)
    assert r1.sim_time == r2.sim_time

    # restore at step 10 (cadence checkpoint) into a fresh chunked trainer
    tr3 = Trainer(_cfg(tmp_path / "chunk", chunk_size=8, **kw),
                  latency=Uniform(1.0, 2.0))
    tr3.restore_checkpoint(step=10)
    assert tr3.step == 10
    tr3.run(3)
    assert _trees_equal(tr1.params, tr3.params)
    assert tr3.sim_time == r1.sim_time


def test_chunked_kill_injection_boundary(tmp_path):
    """A kill at step 7 forces a chunk boundary; the dead worker is never
    selected afterwards and the result still matches legacy bit-exactly."""
    kills = {7: 0}
    tr1, tr2, r1, r2 = _run_pair(tmp_path, steps=14, chunk=8, kills=kills)
    assert _trees_equal(tr1.params, tr2.params)
    assert r1.sim_time == r2.sim_time
    assert r1.metrics == r2.metrics
    # every post-kill event excludes worker 0
    tr2.sim.reset_to_step(7)
    ev = tr2.sim.next_event()
    assert not ev.mask[0]


def test_chunked_no_ema(tmp_path):
    tr1, tr2, r1, r2 = _run_pair(tmp_path, steps=6, chunk=3, ema=0.0)
    assert tr1.ema is None and tr2.ema is None
    assert _trees_equal(tr1.params, tr2.params)
    assert r1.sim_time == r2.sim_time


def test_device_backend_runs_and_converges(tmp_path):
    """'device' backend: arrivals sampled + mask selected inside the scan.
    Not stream-identical to numpy, but the loop must train and the backup
    rule must select exactly N workers per step."""
    tr = Trainer(_cfg(tmp_path, chunk_size=4, backend="device"),
                 latency=PaperCalibrated())
    tr.init_state()
    res = tr.run(12)
    losses = [m["loss"] for m in res.metrics]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert res.sim_time > 0
    assert all(m["selected"] == 4 for m in res.metrics)


def test_device_backend_requires_chunking(tmp_path):
    """chunk_size=1 + device backend would silently fall back to host
    streams — must be rejected at construction instead."""
    with pytest.raises(ValueError, match="chunk_size"):
        Trainer(_cfg(tmp_path, chunk_size=1, backend="device"),
                latency=Uniform(1.0, 2.0))
    with pytest.raises(ValueError, match="straggler_backend"):
        Trainer(_cfg(tmp_path, chunk_size=4, backend="tpu"),
                latency=Uniform(1.0, 2.0))


def test_device_backend_chunk_size_invariant(tmp_path):
    """Device randomness is keyed per step (fold_in), so results must not
    depend on how the run is partitioned into chunks — including ragged
    tails ([4,4,1] vs [3,3,3] for 9 steps)."""
    ra = Trainer(_cfg(tmp_path / "a", chunk_size=4, backend="device"),
                 latency=Uniform(1.0, 2.0))
    ra.init_state()
    res_a = ra.run(9)
    rb = Trainer(_cfg(tmp_path / "b", chunk_size=3, backend="device"),
                 latency=Uniform(1.0, 2.0))
    rb.init_state()
    res_b = rb.run(9)
    assert _trees_equal(ra.params, rb.params)
    assert res_a.sim_time == res_b.sim_time


def test_device_backend_replay_deterministic(tmp_path):
    """Device sampling is pure in (seed, step): two trainers agree."""
    ra = Trainer(_cfg(tmp_path / "a", chunk_size=4, backend="device"),
                 latency=Uniform(1.0, 2.0))
    ra.init_state()
    res_a = ra.run(8)
    rb = Trainer(_cfg(tmp_path / "b", chunk_size=4, backend="device"),
                 latency=Uniform(1.0, 2.0))
    rb.init_state()
    res_b = rb.run(8)
    assert _trees_equal(ra.params, rb.params)
    assert res_a.sim_time == res_b.sim_time


def test_prefetcher_speculation_and_fallback():
    from repro.data.synthetic_lm import (ChunkPrefetcher, SyntheticLMConfig,
                                         chunk_batches)
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=8, global_batch=8,
                            num_workers=2)
    pf = ChunkPrefetcher(cfg)
    # sequential requests with next_k hints (speculation hits)
    for step in (0, 4, 8):
        got = pf.get(step, 4, next_k=4)
        want = chunk_batches(cfg, step, 4)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
        np.testing.assert_array_equal(got["labels"], want["labels"])
    # boundary misprediction: different step AND different k still correct
    got = pf.get(17, 3, next_k=5)
    want = chunk_batches(cfg, 17, 3)
    np.testing.assert_array_equal(got["tokens"], want["tokens"])
    # ragged next_k hint honored (speculation hit on a different length)
    got = pf.get(20, 5)
    want = chunk_batches(cfg, 20, 5)
    np.testing.assert_array_equal(got["tokens"], want["tokens"])
    # no hint -> no in-flight speculation left behind
    assert not pf._pending


def test_prefetcher_depth_two_identical_batches():
    """prefetch_depth=2 serves exactly the batches depth=1 does — deeper
    speculation changes overlap, never content (generation is pure in
    (cfg, step)) — including across ragged boundaries and mispredictions."""
    from repro.data.synthetic_lm import ChunkPrefetcher, SyntheticLMConfig

    cfg = SyntheticLMConfig(vocab_size=64, seq_len=8, global_batch=8,
                            num_workers=2)
    walk = [(0, 4), (4, 4), (8, 2), (10, 4), (14, 4),   # ragged boundary
            (21, 3), (24, 3)]                           # misprediction jump
    pf1 = ChunkPrefetcher(cfg, depth=1)
    pf2 = ChunkPrefetcher(cfg, depth=2)
    for i, (step, k) in enumerate(walk):
        ahead = [(s, kk) for s, kk in walk[i + 1:i + 3]]
        got1 = pf1.get(step, k, next_specs=ahead[:1])
        got2 = pf2.get(step, k, next_specs=ahead)
        for key in ("tokens", "labels"):
            np.testing.assert_array_equal(got1[key], got2[key])
    assert len(pf2._pending) <= 2


def test_trainer_prefetch_depth_identical_run(tmp_path):
    """Trainer runs with prefetch_depth 1 vs 2 are bit-identical (the
    chunked host path's determinism is owned by PipelineState, not the
    prefetch threads)."""
    ra = Trainer(_cfg(tmp_path / "d1", chunk_size=4), latency=Uniform(1.0, 2.0))
    ra.init_state()
    res_a = ra.run(10)
    import dataclasses as _dc
    cfg2 = _dc.replace(_cfg(tmp_path / "d2", chunk_size=4), prefetch_depth=2)
    rb = Trainer(cfg2, latency=Uniform(1.0, 2.0))
    rb.init_state()
    res_b = rb.run(10)
    assert _trees_equal(ra.params, rb.params)
    assert res_a.sim_time == res_b.sim_time
    assert [m["loss"] for m in res_a.metrics] == \
        [m["loss"] for m in res_b.metrics]


def test_chunk_batches_matches_per_step():
    from repro.data.synthetic_lm import (SyntheticLMConfig, chunk_batches,
                                         global_batch)
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=8, global_batch=8,
                            num_workers=2)
    chunk = chunk_batches(cfg, 5, 3)
    assert chunk["tokens"].shape == (3, 8, 8)
    for i, s in enumerate(range(5, 8)):
        per = global_batch(cfg, s)
        np.testing.assert_array_equal(chunk["tokens"][i], per["tokens"])
        np.testing.assert_array_equal(chunk["labels"][i], per["labels"])
