import os
import sys

# NOTE: deliberately NO xla_force_host_platform_device_count here — tests
# see the real single device; multi-device semantics are exercised via
# subprocess tests (test_spmd_subprocess.py) per the dry-run contract.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can reuse benchmark helpers (benchmarks.common)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
