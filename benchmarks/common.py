"""Shared benchmark plumbing: tiny-LM problem, timing, result I/O."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")
ROOT_DIR = os.path.join(os.path.dirname(__file__), "..")

BENCH_SCHEMA_VERSION = 1


def _provenance() -> Dict:
    """{schema_version, git_sha, jax_version, device_kind} — stamped on
    every BENCH json so a recorded number can always be tied back to the
    commit and substrate that produced it. Best-effort: outside a git
    checkout the sha records as "unknown"."""
    try:
        import subprocess
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT_DIR,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except Exception:
        sha = ""
    return {"schema_version": BENCH_SCHEMA_VERSION,
            "git_sha": sha or "unknown",
            "jax_version": jax.__version__,
            "device_kind": jax.devices()[0].device_kind}


def save_json(name: str, payload: Dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def write_bench(name: str, payload: Dict,
                mirror: Optional[Dict] = None) -> str:
    """The one writer for benchmark artifacts: the full ``payload`` goes
    to ``experiments/bench/<name>.json`` and ``mirror`` (the headline
    summary the perf-trajectory tooling tracks; defaults to the full
    payload) to the repo-root ``<name>.json``. Both copies are stamped
    with a ``provenance`` block (schema_version/git_sha/jax_version/
    device_kind). Returns the experiments/bench path."""
    prov = _provenance()
    payload = dict(payload, provenance=prov)
    mirror = dict(mirror, provenance=prov) if mirror is not None else None
    path = save_json(name, payload)
    with open(os.path.join(ROOT_DIR, name + ".json"), "w") as f:
        json.dump(mirror if mirror is not None else payload, f, indent=2,
                  default=float)
    return path


def quick_mode() -> bool:
    return os.environ.get("REPRO_BENCH_FULL", "0") not in ("1", "true")


# ---------------------------------------------------------------------------
# The benchmark workhorse: a tiny LM on the synthetic Markov stream.
# Small enough for CPU, expressive enough that lr/staleness/N effects on
# convergence are measurable (loss floor ~ noise entropy).
# ---------------------------------------------------------------------------


def tiny_lm_config(vocab: int = 64):
    from repro import configs
    from repro.configs.base import replace
    cfg = configs.get_smoke_config("qwen3-0.6b")
    return replace(cfg, vocab_size=vocab, num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_pad_multiple=16)


def tiny_lm_problem(vocab: int = 64, seq: int = 32, batch: int = 16,
                    workers: int = 1, seed: int = 0, noise: float = 0.2):
    """Returns (model, params0, grad_fn, batch_fn, eval_fn).

    grad_fn(params, batch) -> (loss, grads); batch_fn(worker, draw) -> batch;
    eval_fn(params) -> held-out loss.
    """
    from repro.data.synthetic_lm import SyntheticLMConfig, worker_batch
    from repro.models import get_model

    cfg = tiny_lm_config(vocab)
    model = get_model(cfg)
    params0 = model.init(jax.random.PRNGKey(seed))
    data_cfg = SyntheticLMConfig(vocab_size=vocab, seq_len=seq,
                                 global_batch=batch * workers,
                                 num_workers=workers, seed=seed, noise=noise)

    def batch_fn(worker: int, draw: int):
        b = worker_batch(data_cfg, worker, draw)
        return {k: jnp.asarray(v) for k, v in b.items()}

    @jax.jit
    def grad_fn(params, batch):
        def loss(p):
            lt, aux = model.per_token_loss(p, batch)
            return lt.mean() + aux
        return jax.value_and_grad(loss)(params)

    eval_batches = [batch_fn(997, i) for i in range(4)]   # held-out worker id

    @jax.jit
    def eval_one(params, batch):
        lt, _ = model.per_token_loss(params, batch)
        return lt.mean()

    def eval_fn(params):
        return float(np.mean([eval_one(params, b) for b in eval_batches]))

    return model, params0, grad_fn, batch_fn, eval_fn


def sgd_update_fn(lr: float):
    @jax.jit
    def update(params, opt_state, grads, step):
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new, opt_state
    return update


def time_to_threshold(times: np.ndarray, losses: np.ndarray,
                      eps: float) -> Optional[float]:
    """First (smoothed) time the loss crosses below eps; None if never."""
    if len(losses) == 0:
        return None
    k = max(1, len(losses) // 50)
    smooth = np.convolve(losses, np.ones(k) / k, mode="same")
    idx = np.argmax(smooth <= eps)
    if smooth[idx] > eps:
        return None
    return float(times[idx])
