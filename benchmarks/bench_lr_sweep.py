"""Paper Table 2 / Fig. 7: small gamma0 converges FASTER to a WORSE optimum.

The paper's methodology point: report both time-to-epsilon and the final
metric, or early-phase speed misleads. Tiny-LM sweep over gamma0 with the
paper's exponential decay; we record steps-to-epsilon for a loose epsilon
(small lr wins) and the best loss reached (large lr wins).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from benchmarks import common
from repro.optim import schedules


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    gammas = [0.05, 0.2, 0.8] if quick else [0.05, 0.1, 0.2, 0.4, 0.8, 1.6]
    steps = 400 if quick else 1200
    eps_loose = 3.0
    rows = []
    results = {}
    for g in gammas:
        model, params, grad_fn, batch_fn, eval_fn = common.tiny_lm_problem(
            batch=16, seed=0)
        sched = schedules.exponential_decay(g, 0.94, steps_per_epoch=50)

        @jax.jit
        def update(p, grads, step):
            lr = sched(step)
            return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, grads)

        t0 = time.time()
        losses = []
        import jax.numpy as jnp
        for s in range(steps):
            _, grads = grad_fn(params, batch_fn(0, s))
            params = update(params, grads, jnp.asarray(s))
            if s % 10 == 0:
                losses.append(eval_fn(params))
        losses = np.array(losses)
        t_eps = common.time_to_threshold(np.arange(len(losses)) * 10.0,
                                         losses, eps_loose)
        best = float(losses.min())
        results[g] = {"steps_to_loose_eps": t_eps, "best_loss": best}
        rows.append((f"lr_sweep.g{g}", (time.time() - t0) * 1e6 / steps,
                     f"best={best:.3f},t_eps={t_eps}"))

    gs = sorted(results)
    # paper-shape checks: the largest lr reaches the best optimum; the
    # smallest lr is not the best optimum
    best_gamma = min(results, key=lambda g_: results[g_]["best_loss"])
    rows.append(("lr_sweep.best_gamma", 0.0, str(best_gamma)))
    rows.append(("lr_sweep.small_lr_worse_optimum", 0.0,
                 str(results[gs[0]]["best_loss"]
                     > results[best_gamma]["best_loss"] + 1e-3)))
    common.save_json("lr_sweep", {
        "results": {str(k): v for k, v in results.items()},
        "paper_claim": "Table 2: gamma0=1.125 converges in fewest epochs but"
                       " to 77.29%; gamma0=9.0 reaches 78.17%",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
